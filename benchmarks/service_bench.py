"""BENCH ``service`` section: the placement service's amortization story,
CI-gated.

Replays the deterministic 32-query / 8-distinct-graph stream
(:func:`repro.core.workloads.service_stream` — 75% repeats) through one
:class:`~repro.service.service.PlacementService` and reports:

  * per distinct graph: ``svc_cycles_cached`` (the answer a repeat query
    served from the content-hash cache) and ``svc_cycles_fresh`` (the same
    query recomputed from scratch in a cold service). ``check_bench``'s
    service gate requires the pair to be EQUAL within the run and bit-exact
    against the committed snapshot in both directions — a cached integer
    must be indistinguishable from a fresh one;
  * the stream summary: exact hit/miss/simulation counters (bit-exact
    gated) plus ``hit_rate`` (floor-gated at
    ``check_bench.SERVICE_HIT_RATE_FLOOR``) — every repeat must answer
    from the cache with zero simulations;
  * the design-space explorer's Pareto frontier on the first stream graph:
    per-point ``cycles_frontier`` (no-increase gated like every tracked
    cycle count).

Wall time (cold stream vs from-scratch replay; ``derived`` = amortization
speedup) stays informational — shared CI runners.
"""
from __future__ import annotations

import time

from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.service import PlacementQuery, PlacementService, explore

NX = NY = 4
BUDGET = 2048
MAX_CYCLES = 1_000_000

#: explorer space for the frontier rows: small enough for CI, wide enough
#: to produce a non-trivial cycles-vs-area trade-off.
FRONTIER_SPACE = {
    "scheduler": ("ooo", "inorder"),
    "eject_policy": ("n_first",),
    "grid": ((2, 2), (4, 4)),
    "placement": ("identity", "anneal"),
}


def _query(g):
    return PlacementQuery(
        graph=g, nx=NX, ny=NY, budget=BUDGET,
        cfg=OverlayConfig(placement="anneal", max_cycles=MAX_CYCLES))


def run_stream():
    stream = wl.service_stream(n_queries=32, distinct=8, seed=0)

    svc = PlacementService()
    t0 = time.time()
    cached: dict = {}   # name -> cycles served to a repeat query
    first: dict = {}    # name -> (graph, first answer)
    for name, g in stream:
        r = svc.query(_query(g))
        if name in first:
            assert r.cached, f"{name}: repeat query missed the cache"
            assert r.cycles == first[name][1].cycles, name
            cached[name] = r.cycles
        else:
            assert not r.cached, f"{name}: first sighting claimed a hit"
            first[name] = (g, r)
    stream_wall = time.time() - t0
    rep = svc.report()
    n_repeats = len(stream) - len(first)
    assert rep["cache_hits"] == n_repeats, rep
    assert rep["simulations"] == len(first), rep

    # Steady-state replay: the same 32 queries against the warm service are
    # all cache hits — this is the amortized cost a long-lived service pays.
    t0 = time.time()
    for name, g in stream:
        r = svc.query(_query(g))
        assert r.cached, f"{name}: warm replay missed the cache"
    replay_wall = time.time() - t0

    # From-scratch recomputation of every distinct query in a cold service:
    # the cached integers must be indistinguishable from these. (Runs on a
    # warm jit cache, so per-query wall here is the no-result-cache floor.)
    t0 = time.time()
    fresh = {name: PlacementService().query(_query(g)).cycles
             for name, (g, _) in first.items()}
    fresh_wall = time.time() - t0

    rows = []
    for name, (g, r0) in sorted(first.items()):
        assert cached[name] == fresh[name] == r0.cycles, (
            name, cached[name], fresh[name], r0.cycles)
        rows.append({
            "name": f"service_{name}",
            "us_per_call": 0.0,
            "derived": "cached==fresh",
            "wall_s": 0.0,
            "nodes": g.num_nodes,
            "cycles_anneal": int(r0.cycles),          # no-increase gated
            "svc_cycles_cached": int(cached[name]),   # bit-exact gated
            "svc_cycles_fresh": int(fresh[name]),     # bit-exact gated
        })

    naive_wall = fresh_wall / max(1, len(first)) * len(stream)
    rows.append({
        "name": "service_stream",
        "us_per_call": round(1e6 * stream_wall, 1),
        # amortization: est. warm no-cache wall for all 32 queries / the
        # warm all-hit replay wall (steady-state service speedup)
        "derived": round(naive_wall / max(replay_wall, 1e-9), 1),
        "wall_s": round(stream_wall, 3),
        "replay_wall_s": round(replay_wall, 4),
        "fresh_wall_s": round(fresh_wall, 3),
        "queries": len(stream),
        "distinct": len(first),
        # exact counters, bit-exact gated in both directions
        "svc_hits": rep["cache_hits"],
        "svc_misses": rep["cache_misses"],
        "svc_simulations": rep["simulations"],
        "svc_anneals": rep["anneals"],
        "svc_batched_anneals": rep["batched_anneals"],
        # floor-gated: repeats must actually hit
        "hit_rate": round(rep["cache_hit_rate"], 4),
    })
    return rows


def run_frontier():
    name0, g0 = wl.service_stream(n_queries=1, distinct=1, seed=0)[0]
    t0 = time.time()
    rec = explore(g0, space=FRONTIER_SPACE, budget=BUDGET,
                  max_cycles=MAX_CYCLES)
    wall = time.time() - t0
    rows = []
    for p in rec["frontier"]:
        rows.append({
            "name": f"service_frontier_{p['name']}",
            "us_per_call": 0.0,
            "derived": f"{p['num_pes']}pes",
            "wall_s": 0.0,
            "graph": name0,
            "num_pes": p["num_pes"],
            "cycles_frontier": int(p["cycles"]),      # no-increase gated
        })
    rows.append({
        "name": "service_frontier",
        "us_per_call": round(1e6 * wall, 1),
        "derived": f"{len(rec['frontier'])}/{len(rec['points'])}",
        "wall_s": round(wall, 3),
        "svc_frontier_points": len(rec["frontier"]),  # bit-exact gated
        "svc_swept_points": len(rec["points"]),       # bit-exact gated
    })
    return rows


def run():
    return run_stream() + run_frontier()
