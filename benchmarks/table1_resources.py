"""Paper Table I + §III capacity claims: the memory-overhead model.

FPGA area/frequency cannot be measured in simulation; what CAN be reproduced
exactly is the paper's BRAM arithmetic:
  * RDY bit-flag overhead: 2 * ceil(512/32) = 32 of 512 words ~ 6.25%,
  * deadlock-free in-order FIFO provisioning -> ~100K nodes+edges at 256 PEs,
  * OoO (no FIFOs) -> ~5x larger graphs.
Paper reference values are included in the CSV's ``derived`` comments.

Output CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

from repro.core import partition as pt

PAPER = {
    "flag_overhead": 0.0625,          # "~6% overhead"
    "inorder_capacity": 100_000,       # "~100K nodes and edges"
    "capacity_ratio": 5.0,             # "~5x larger input graphs"
}


def run(num_pes: int = 256):
    t0 = time.time()
    rows = []
    ov = pt.rdy_flag_overhead()
    rows.append(("table1_flag_overhead", ov, PAPER["flag_overhead"]))
    ino = pt.capacity_elements(num_pes, "inorder")
    ooo = pt.capacity_elements(num_pes, "ooo")
    rows.append(("table1_inorder_capacity_elems", ino["elements"], PAPER["inorder_capacity"]))
    rows.append(("table1_ooo_capacity_elems", ooo["elements"], None))
    rows.append(("table1_capacity_ratio", ooo["elements"] / ino["elements"], PAPER["capacity_ratio"]))
    rows.append(("table1_fifo_words_freed", ino["fifo_words"], None))
    us = 1e6 * (time.time() - t0)
    return rows, us


def main():
    rows, us = run()
    print("name,us_per_call,derived")
    for name, value, paper in rows:
        note = f" (paper: {paper})" if paper is not None else ""
        print(f"{name},{us:.1f},{value}{note}")


if __name__ == "__main__":
    main()
