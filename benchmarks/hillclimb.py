"""Perf-iteration driver (§Perf): re-lower one dry-run cell with config
overrides and diff the roofline terms against the stored baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch starcoder2-7b \
      --shape train_4k --set attn_chunk=2048 loss_chunk=1024 --tag iter1

Overrides are typed dataclasses.replace on the arch config; --profile prints
the top HBM-traffic contributors (trip-count-aware) for hypothesis building.
Results append to experiments/perf/<arch>__<shape>__<tag>.json.

Overlay mode (``--overlay``) is a thin CLI over
:func:`repro.service.explore`: the design-space explorer sweeps (scheduler
policy x eject policy x grid x placement strategy — including the NoC-aware
annealer) through the placement service, so every point is one cached /
batched / amortized query and repeat sweeps of the same graph are nearly
free. Where the old greedy coordinate descent walked one path to one
config, the explorer returns the full bit-deterministic Pareto frontier
over (simulated cycles, PE count). Output keeps the standard
machine-readable benchmark shape: ``name,us_per_call,derived`` CSV on
stdout (``hillclimb_step{i}`` = the swept points in deterministic order,
``hillclimb_best`` = the minimum-cycle point) plus a JSON record under
--out.

  PYTHONPATH=src python -m benchmarks.hillclimb --overlay --blocks 8 --tag hc1
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402


def _coerce(v):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        out[k] = _coerce(v)
    return out


def apply_overrides(cfg, ov):
    """Supports nested keys like ssm.chunk=64 / moe.capacity_factor=1.0."""
    flat = {k: v for k, v in ov.items() if "." not in k}
    nested: dict = {}
    for k, v in ov.items():
        if "." in k:
            head, tail = k.split(".", 1)
            nested.setdefault(head, {})[tail] = v
    for head, kv in nested.items():
        sub = getattr(cfg, head)
        flat[head] = dataclasses.replace(sub, **kv)
    return dataclasses.replace(cfg, **flat) if flat else cfg


# ---------------------------------------------------------------------------
# Overlay-config hillclimb: thin CLI over repro.place.config_hillclimb.
# ---------------------------------------------------------------------------


def overlay_hillclimb(args):
    import time

    from repro.core import workloads as wl
    from repro.service import explore

    g = wl.arrow_lu_graph(args.blocks, args.block_size, args.border,
                          seed=args.seed)
    # sweep the default explorer axes, pinned to the requested grid
    t0 = time.time()
    rec = explore(g, space={"grid": ((args.nx, args.ny),)},
                  max_cycles=args.max_cycles)
    rec.update({
        "mode": "overlay",
        "wall_s": round(time.time() - t0, 3),
        "workload": {"family": "arrow_lu", "blocks": args.blocks,
                     "block_size": args.block_size, "border": args.border,
                     "nodes": g.num_nodes, "edges": g.num_edges,
                     "grid": [args.nx, args.ny]},
        "tag": args.tag,
    })
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"overlay__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    # Standard machine-readable benchmark output: CSV rows on stdout
    # (derived = simulated cycles per swept point, deterministic order;
    # final row is the minimum-cycle point of the sweep).
    print("name,us_per_call,derived")
    for i, p in enumerate(rec["points"]):
        print(f"hillclimb_step{i},0.0,{p['cycles']}")
    best = min(rec["points"], key=lambda p: (p["cycles"], p["name"]))
    print(f"hillclimb_best,{round(1e6 * rec['wall_s'], 1)},{best['cycles']}")
    print(f"# wrote {path}", file=sys.stderr)
    print(f"# best_config={best['name']} frontier="
          f"{[p['name'] for p in rec['frontier']]}", file=sys.stderr)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--overlay", action="store_true",
                    help="hillclimb overlay sim configs instead of dryrun cells")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", nargs="*", default=[], help="cfg field overrides k=v")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--baseline", default="experiments/dryrun")
    # overlay-mode knobs
    ap.add_argument("--blocks", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--border", type=int, default=6)
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--ny", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-cycles", type=int, default=4_000_000)
    args = ap.parse_args()

    if args.overlay:
        overlay_hillclimb(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required unless --overlay is given")

    cfg = get_config(args.arch)
    ov = parse_overrides(args.set)
    cfg = apply_overrides(cfg, ov)

    rec = dryrun.run_cell(args.arch, args.shape, args.mesh,
                          cfg_override=cfg, want_profile=args.profile)
    rec["overrides"] = ov
    rec["tag"] = args.tag
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    base_path = os.path.join(args.baseline, f"{args.arch}__{args.shape}__{args.mesh}.json")
    base = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)

    if rec["status"] != "OK":
        print("STATUS:", rec["status"], rec.get("error", ""))
        return
    r = rec["roofline"]
    print(f"\n=== {args.arch} x {args.shape} x {args.mesh}  [{args.tag}]  {ov} ===")
    hdr = f"{'term':12s} {'baseline':>12s} {'now':>12s} {'delta':>8s}"
    print(hdr)
    for term in ("compute_s", "memory_s", "collective_s"):
        b = base["roofline"][term] if base and base.get("status") == "OK" else float("nan")
        n = r[term]
        d = (n - b) / b * 100 if b and b == b else float("nan")
        print(f"{term:12s} {b:12.4f} {n:12.4f} {d:7.1f}%")
    print(f"useful_flops_frac: {r['useful_flops_frac']}")
    if args.profile and "profile" in rec:
        print("\ntop HBM-traffic contributors (GB, trip-aware):")
        for k, v in list(rec["profile"].items())[:15]:
            print(f"  {v['bytes']/1e9:10.2f} GB  {v['flops']/1e12:8.2f} TF  {k[:90]}")


if __name__ == "__main__":
    main()
