# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

  table1    — RDY-flag overhead / FIFO-elimination capacity model (Table I, §III)
  kernels   — scheduler (hierarchical LOD) pick-rate microbench
  fig1      — OoO vs in-order speedup vs graph size (paper Fig. 1)
  roofline  — per (arch x shape) roofline terms from the dry-run artifacts

``python -m benchmarks.run [--full]`` runs everything (fig1 sweeps to ~470K
nodes with --full; default tops out near ~235K to keep wall-time sane).
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    print("name,us_per_call,derived")

    from benchmarks import table1_resources
    for name, value, paper in table1_resources.run()[0]:
        note = f" (paper: {paper})" if paper is not None else ""
        print(f"{name},0.0,{value}{note}", flush=True)

    from benchmarks import kernel_bench
    for r in kernel_bench.run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    from benchmarks import fig1_ooo_speedup
    for r in fig1_ooo_speedup.run(full=full):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    from benchmarks import roofline
    rows = roofline.run("single")
    if rows:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)
    else:
        print("roofline_pending,0.0,run repro.launch.dryrun first", flush=True)


if __name__ == "__main__":
    main()
