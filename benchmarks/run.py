# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

  table1    — RDY-flag overhead / FIFO-elimination capacity model (Table I, §III)
  kernels   — per-policy scheduler pick-rate microbench (LOD + select/commit
              + the fused Pallas schedule_step / rotating variants)
  fig1      — OoO vs in-order speedup vs graph size (paper Fig. 1), with
              hot-timed simulated-cycles-per-second throughput per row
  sweep     — every registered policy on one graph via one batched program
  chunking  — chunked-engine throughput: check_every=1 vs autotuned depth
  megakernel— fused single-pallas_call chunk engine vs the jnp reference
              (cycle counts CI-gated bit-exact; throughput informational)
  placement — repro.place subsystem: identity vs random vs annealed
              placements (CI-gated cycles) + priority eject arbitration
  guided    — surrogate-guided annealing vs the plain annealer: cycles and
              exact full-cost-evaluation counters (CI-gated)
  telemetry — fig1 ooo-vs-inorder with repro.telemetry tracing on: cycles
              unchanged vs untraced (CI-gated), instrument counters
              bit-exact (CI-gated), tracing overhead informational
  service   — replayed 32-query placement-service stream: repeats answer
              from the content-hash cache with zero simulations, cached ==
              fresh cycles bit-exact both directions (CI-gated), hit-rate
              floor, plus the explorer's Pareto frontier cycle counts
  fig1_full — (--full only) budgeted multilevel placement + simulation of
              the ~470K-node paper-scale LU DAG (CI-gated cycles)
  roofline  — per (arch x shape) roofline terms from the dry-run artifacts

``python -m benchmarks.run [--full]`` runs everything (fig1 sweeps to ~470K
nodes and the fig1_full tracked row lands with --full; default tops out
near ~235K to keep wall-time sane).

Besides the CSV on stdout, the driver snapshots everything machine-readable
to ``BENCH_overlay.json`` (per-scheduler cycles, wall time, speedups) so the
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import sys

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_overlay.json")


def main() -> None:
    full = "--full" in sys.argv
    print("name,us_per_call,derived")

    import jax

    from repro.core import schedulers

    bench: dict = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "policies": sorted(schedulers.REGISTRY),
            "full": full,
        }
    }

    from benchmarks import table1_resources
    bench["table1"] = []
    for name, value, paper in table1_resources.run()[0]:
        note = f" (paper: {paper})" if paper is not None else ""
        print(f"{name},0.0,{value}{note}", flush=True)
        bench["table1"].append({"name": name, "value": value, "paper": paper})

    from benchmarks import kernel_bench
    bench["kernels"] = kernel_bench.run()
    for r in bench["kernels"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    from benchmarks import fig1_ooo_speedup
    bench["fig1"] = fig1_ooo_speedup.run(full=full)
    for r in bench["fig1"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    bench["policy_sweep"] = fig1_ooo_speedup.sweep_policies()
    for row in bench["policy_sweep"]["schedulers"]:
        print(f"sweep_{row['scheduler']},0.0,{row['speedup_vs_inorder']}",
              flush=True)

    # Chunked-engine before/after on one fig1 graph: hot-timed simulated
    # cycles per second at check_every=1 vs the autotuned chunk depth.
    bench["chunking"] = fig1_ooo_speedup.chunking_throughput()
    for r in bench["chunking"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)
    print(f"chunking_speedup_hot,0.0,{bench['chunking']['speedup_hot']}",
          flush=True)

    # Megakernel engine: the fused single-pallas_call chunk vs the jnp
    # reference on the small fig1 graphs — cycle counts bit-exact (CI-gated),
    # the jnp-vs-fused cycles_per_sec pair informational (min-over-reps hot
    # timing; interpret mode on CPU runners).
    bench["megakernel"] = {"rows": fig1_ooo_speedup.megakernel_rows()}
    for r in bench["megakernel"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    # Placement subsystem: identity vs random vs NoC-annealed placements
    # (cycle counts CI-gated), and the criticality-aware eject arbitration
    # on congested grids.
    from benchmarks import placement_bench
    bench["placement"] = {"rows": placement_bench.run_placement()}
    for r in bench["placement"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)
    bench["eject"] = {"rows": placement_bench.run_eject()}
    for r in bench["eject"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    # Surrogate subsystem: held-out rank correlation + prediction-pruned
    # search quality per fig1 workload, and the multilevel
    # coarsen->anneal->refine placement at >= 100K nodes vs round-robin.
    # check_bench gates the Spearman floor, the pruning gap, and the
    # multilevel cycle counts.
    bench["surrogate"] = {"rows": placement_bench.run_surrogate()
                          + placement_bench.run_multilevel()}
    for r in bench["surrogate"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    # Surrogate-guided annealing vs the plain annealer: equal-or-better
    # cycles under <= 0.5x full-cost evaluations (both counters exact and
    # deterministic; check_bench gates the cycles, the ratio cap, and the
    # guided <= unguided relation).
    bench["guided"] = {"rows": placement_bench.run_guided()}
    for r in bench["guided"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    # Telemetry instrument: fig1 ooo-vs-inorder with tracing on. Cycle
    # counts must equal the untraced run (asserted in the bench, gated like
    # every cycles_* key); the ctr_* counter values (stall attribution,
    # deflection split, busiest link) are bit-exact gated by check_bench;
    # the derived column (traced/untraced hot-wall ratio) is informational.
    from benchmarks import telemetry_bench
    bench["telemetry"] = {"rows": telemetry_bench.run()}
    for r in bench["telemetry"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    # Placement service: the 32-query / 8-distinct replayed stream
    # (repeats must answer from the content-hash cache with zero
    # simulations; cached-vs-fresh cycles gated bit-exact both directions;
    # hit rate floor-gated) plus the explorer's Pareto frontier rows
    # (cycles no-increase gated). Wall/amortization stays informational.
    from benchmarks import service_bench
    bench["service"] = {"rows": service_bench.run()}
    for r in bench["service"]["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)

    if full:
        # fig1-full tracked row: budgeted multilevel placement + simulation
        # of the ~470K-node paper-scale LU DAG (cycle counts CI-gated
        # bit-exactly; the DAG itself is served from the on-disk graph
        # cache, which CI persists across runs).
        bench["fig1_full"] = {"rows": placement_bench.run_fig1_full()}
        for r in bench["fig1_full"]["rows"]:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}",
                  flush=True)

    from benchmarks import roofline
    rows = roofline.run("single")
    bench["roofline"] = rows or []
    if rows:
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)
    else:
        print("roofline_pending,0.0,run repro.launch.dryrun first", flush=True)

    with open(BENCH_PATH, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"# wrote {BENCH_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
