"""Paper Fig. 1: out-of-order vs in-order scheduling speedup vs graph size.

Workloads: LU-factorization dataflow DAGs of bordered block-diagonal
("arrow") matrices — the canonical circuit/power-grid structure behind
sparse-matrix-factorization kernels — on the 16x16 (256 PE) overlay, exactly
the paper's evaluation setup. The paper's own matrices are not published;
sizes sweep a few K to ~500K nodes as in Fig. 1.

Output CSV: name,us_per_call,derived  where derived = inorder/ooo speedup.
"""
from __future__ import annotations

import time

from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig, simulate
from repro.core.partition import build_graph_memory

# (blocks, block_size, border): graph sizes ~15K .. ~470K nodes
SWEEP = [(4, 10, 8), (8, 10, 8), (16, 10, 8), (32, 10, 8), (64, 10, 8)]
SWEEP_FULL = SWEEP + [(96, 10, 8), (128, 10, 8)]


def run(full: bool = False, nx: int = 16, ny: int = 16):
    rows = []
    for blocks, s, w in (SWEEP_FULL if full else SWEEP):
        g = wl.arrow_lu_graph(blocks, s, w, seed=3)
        cyc = {}
        wall = {}
        for sched in ("ooo", "inorder"):
            gm = build_graph_memory(g, nx, ny, criticality_order=(sched == "ooo"))
            t0 = time.time()
            r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=8_000_000))
            wall[sched] = time.time() - t0
            assert r.done, (blocks, sched)
            cyc[sched] = r.cycles
        rows.append({
            "name": f"fig1_arrow_n{g.num_nodes}",
            "us_per_call": round(1e6 * (wall["ooo"] + wall["inorder"]), 1),
            "derived": round(cyc["inorder"] / cyc["ooo"], 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "cycles_ooo": cyc["ooo"],
            "cycles_inorder": cyc["inorder"],
        })
    return rows


def main(full: bool = False):
    print("name,us_per_call,derived")
    for r in run(full):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
