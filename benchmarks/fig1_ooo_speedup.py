"""Paper Fig. 1: out-of-order vs in-order scheduling speedup vs graph size.

Workloads: LU-factorization dataflow DAGs of bordered block-diagonal
("arrow") matrices — the canonical circuit/power-grid structure behind
sparse-matrix-factorization kernels — on the 16x16 (256 PE) overlay, exactly
the paper's evaluation setup. The paper's own matrices are not published;
sizes sweep a few K to ~500K nodes as in Fig. 1.

Each graph size runs the requested scheduler policies through
``repro.run(gm, batch=...)``: the cycle body is vmapped over the policy
axis, so a
sweep compiles once per (graph, memory layout) instead of retracing per
scheduler. Policies are grouped by ``wants_criticality_order`` and each
group gets the matching GraphMemory layout — the seed methodology (``ooo``
on criticality-ordered memory, the FCFS baseline on naive node-id order);
slot numbering shifts packet-arrival order, so mixing layouts would move
the tracked speedup by a few percent.

Output CSV: name,us_per_call,derived  where derived = inorder/ooo speedup.
"""
from __future__ import annotations

import time

from repro.api import run as overlay_run
from repro.core import schedulers
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory

# (blocks, block_size, border): graph sizes ~15K .. ~470K nodes
SWEEP = [(4, 10, 8), (8, 10, 8), (16, 10, 8), (32, 10, 8), (64, 10, 8)]
SWEEP_FULL = SWEEP + [(96, 10, 8), (128, 10, 8)]

DEFAULT_POLICIES = ("ooo", "inorder")


def _run_policies(g, nx, ny, policies, max_cycles=8_000_000, timed=False,
                  check_every=None, engine="jnp"):
    """One batched program per GraphMemory layout group. Returns
    ({policy: cycles}, wall seconds[, hot wall seconds]).

    ``timed=True`` reruns every (already compiled) program once more and
    additionally returns the hot wall — the simulated-cycles-per-second
    throughput metric tracked in BENCH_overlay.json, free of compile time.
    """
    groups: dict = {}
    for p in policies:
        wants = schedulers.get(p).wants_criticality_order
        groups.setdefault(wants, []).append(p)
    cyc = {}
    runs = []
    t0 = time.time()
    for wants, group in groups.items():
        gm = build_graph_memory(g, nx, ny, criticality_order=wants)
        cfgs = [OverlayConfig(scheduler=p, max_cycles=max_cycles,
                              check_every=check_every, engine=engine)
                for p in group]
        for p, r in zip(group, overlay_run(gm, batch=cfgs)):
            assert r.done, p
            cyc[p] = r.cycles
        runs.append((gm, cfgs))
    wall = time.time() - t0
    if not timed:
        return cyc, wall
    hot = float("inf")
    for _ in range(2):  # min over reps: shared machines have noisy clocks
        t0 = time.time()
        for gm, cfgs in runs:
            overlay_run(gm, batch=cfgs)
        hot = min(hot, time.time() - t0)
    return cyc, wall, hot


def run(full: bool = False, nx: int = 16, ny: int = 16,
        policies: tuple[str, ...] = DEFAULT_POLICIES):
    rows = []
    for blocks, s, w in (SWEEP_FULL if full else SWEEP):
        # Cached on disk (experiments/graph_cache/): the --full sweep's big
        # DAGs take minutes of Python elimination to build, and CI persists
        # the cache across runs keyed on the workload code.
        g = wl.cached_graph(
            f"arrow_b{blocks}_s{s}_w{w}_seed3",
            lambda blocks=blocks, s=s, w=w: wl.arrow_lu_graph(
                blocks, s, w, seed=3))
        cyc, wall, hot_wall = _run_policies(g, nx, ny, policies, timed=True)
        total_cycles = sum(cyc.values())
        row = {
            "name": f"fig1_arrow_n{g.num_nodes}",
            "us_per_call": round(1e6 * wall, 1),
            "derived": round(cyc["inorder"] / cyc["ooo"], 4)
            if {"ooo", "inorder"} <= cyc.keys() else 0.0,
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "wall_s": round(wall, 3),
            "hot_wall_s": round(hot_wall, 3),
            "cycles_per_sec": round(total_cycles / hot_wall, 1),
        }
        row.update({f"cycles_{p}": c for p, c in cyc.items()})
        rows.append(row)
    return rows


def chunking_throughput(nx: int = 16, ny: int = 16,
                        blocks: int = 8, block_size: int = 10, border: int = 8):
    """Chunked-engine before/after: the same fig1 graph stepped with
    ``check_every=1`` (the per-cycle reference engine) versus the autotuned
    chunk depth, hot-timed. The simulated-cycles-per-second ratio is the
    tracked win of chunked termination checking on this backend (the larger
    wins are on sharded meshes, where the chunk also amortizes the
    cross-shard collectives — see docs/schedulers.md)."""
    from repro.core.overlay import resolve_check_every

    g = wl.arrow_lu_graph(blocks, block_size, border, seed=3)
    rows = []
    for label, check_every in (("check_every_1", 1), ("check_every_auto", None)):
        cyc, wall, hot = _run_policies(g, nx, ny, ("ooo", "inorder"),
                                       timed=True, check_every=check_every)
        total = sum(cyc.values())
        rows.append({
            "name": f"chunking_{label}_n{g.num_nodes}",
            "us_per_call": round(1e6 * hot, 1),
            "derived": round(total / hot, 1),   # simulated cycles per second
            "wall_s": round(wall, 3),
            "hot_wall_s": round(hot, 3),
            "cycles_per_sec": round(total / hot, 1),
            "cycles": dict(sorted(cyc.items())),
        })
    base, auto = rows[0], rows[1]
    k = resolve_check_every(OverlayConfig(), nx, ny,
                            build_graph_memory(g, nx, ny).lmax)
    return {
        "rows": rows,
        "auto_check_every": k,
        "speedup_hot": round(auto["cycles_per_sec"] / base["cycles_per_sec"], 4),
    }


def megakernel_rows(nx: int = 16, ny: int = 16):
    """Fused megakernel engine vs the jnp reference on the small fig1
    graphs: cycle counts must be bit-identical (CI-gated via the cycles_*
    keys), the jnp-vs-fused ``cycles_per_sec`` pair is informational
    (min-over-reps hot timing, interpret mode on CPU — the compiled-TPU
    rates are the open follow-up). Graphs come from the on-disk cache
    (``workloads.MEGAKERNEL_BENCH_GRAPHS``), pre-warmed by CI."""
    rows = []
    for name in wl.MEGAKERNEL_BENCH_GRAPHS:
        parts = dict((p[0], int(p[1:])) for p in name.split("_")[1:]
                     if p[0] in "bsw" and p[1:].isdigit())
        g = wl.cached_graph(name, lambda b=parts["b"], s=parts["s"],
                            w=parts["w"]: wl.arrow_lu_graph(b, s, w, seed=3))
        cyc_jnp, _, hot_jnp = _run_policies(g, nx, ny, ("ooo", "inorder"),
                                            timed=True)
        cyc_mega, wall, hot_mega = _run_policies(
            g, nx, ny, ("ooo", "inorder"), timed=True, engine="megakernel")
        assert cyc_mega == cyc_jnp, (name, cyc_mega, cyc_jnp)
        total = sum(cyc_mega.values())
        row = {
            "name": f"megakernel_arrow_n{g.num_nodes}",
            "us_per_call": round(1e6 * hot_mega, 1),
            # fused-vs-jnp hot speedup (>1 means the megakernel wins)
            "derived": round(hot_jnp / hot_mega, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "wall_s": round(wall, 3),
            "hot_wall_s": round(hot_mega, 3),
            "hot_wall_s_jnp": round(hot_jnp, 3),
            "cycles_per_sec": round(total / hot_mega, 1),
            "jnp_cycles_per_sec": round(total / hot_jnp, 1),
        }
        row.update({f"cycles_{p}": c for p, c in sorted(cyc_mega.items())})
        rows.append(row)
    return rows


def sweep_policies(nx: int = 16, ny: int = 16,
                   blocks: int = 8, block_size: int = 10, border: int = 8):
    """All registered policies on one mid-size arrow-LU graph (one batched
    program per layout group). Returns per-scheduler cycles + speedup vs the
    FCFS baseline."""
    policies = tuple(sorted(schedulers.REGISTRY))
    g = wl.arrow_lu_graph(blocks, block_size, border, seed=3)
    cyc, wall = _run_policies(g, nx, ny, policies)
    base = cyc["inorder"]
    return {
        "nodes": g.num_nodes,
        "edges": g.num_edges,
        "grid": [nx, ny],
        "wall_s": round(wall, 3),
        "schedulers": [
            {"scheduler": p, "cycles": c, "done": True,
             "speedup_vs_inorder": round(base / c, 4)}
            for p, c in sorted(cyc.items())
        ],
    }


def main(full: bool = False):
    print("name,us_per_call,derived")
    for r in run(full):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
