"""BENCH ``telemetry`` section: the instrument's own numbers, CI-gated.

For the fig1-family ooo-vs-inorder pair (the cached ``arrow_b4_s10_w8``
graph on the 16x16 grid), run each policy with tracing on and report:

  * ``cycles_<policy>`` — must equal the untraced run (asserted here,
    no-increase gated by check_bench like every cycles_* key);
  * ``ctr_*`` — integer counter values from the traces (stall attribution,
    deflection split, busiest-link cycles, pick counts): bit-exact gated by
    ``check_bench._telemetry_counters`` — the instrument itself must not
    drift silently;
  * ``derived`` / ``*_util_*`` — tracing overhead ratio and utilization
    percentiles, informational (wall-clock / derived floats).

The ooo-vs-inorder stall attribution printed here is the worked example in
docs/telemetry.md.
"""
from __future__ import annotations

import time

from repro.core import schedulers
from repro.core import workloads as wl
from repro.api import run as overlay_run
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory
from repro.telemetry import TelemetrySpec


def run(nx: int = 16, ny: int = 16):
    name = wl.MEGAKERNEL_BENCH_GRAPHS[0]
    g = wl.cached_graph(name, lambda: wl.arrow_lu_graph(4, 10, 8, seed=3))
    spec = TelemetrySpec()
    rows = []
    for sched in ("ooo", "inorder"):
        gm = build_graph_memory(
            g, nx, ny,
            criticality_order=schedulers.get(sched).wants_criticality_order)
        cfg_off = OverlayConfig(scheduler=sched, max_cycles=8_000_000)
        cfg_on = OverlayConfig(scheduler=sched, max_cycles=8_000_000,
                               telemetry=spec)
        t0 = time.time()
        off = overlay_run(gm, cfg_off)
        r = overlay_run(gm, cfg_on)
        wall = time.time() - t0
        assert r.done and r.cycles == off.cycles, (sched, r.cycles, off.cycles)

        hot_off = hot_on = float("inf")
        for _ in range(2):  # min over reps: shared machines have noisy clocks
            t0 = time.time()
            overlay_run(gm, cfg_off)
            hot_off = min(hot_off, time.time() - t0)
            t0 = time.time()
            overlay_run(gm, cfg_on)
            hot_on = min(hot_on, time.time() - t0)

        rep = r.telemetry.report()
        rows.append({
            "name": f"telemetry_arrow_n{g.num_nodes}_{sched}",
            "us_per_call": round(1e6 * hot_on, 1),
            # tracing overhead: traced / untraced hot wall (1.0 == free)
            "derived": round(hot_on / hot_off, 4),
            "nodes": g.num_nodes,
            "wall_s": round(wall, 3),
            "hot_wall_s": round(hot_on, 3),
            "hot_wall_s_untraced": round(hot_off, 3),
            f"cycles_{sched}": r.cycles,
            # bit-exact-gated instrument counters (check_bench ctr_* gate)
            "ctr_busy_total": r.busy_cycles,
            "ctr_delivered": r.delivered,
            "ctr_noc_deflections": r.noc_deflections,
            "ctr_eject_deflections": r.eject_deflections,
            "ctr_link_busy_max": rep["links"]["busy_max"],
            "ctr_stall_no_ready": rep["stalls"]["no_ready"],
            "ctr_stall_inject_blocked": rep["stalls"]["inject_blocked"],
            "ctr_stall_select_wait": rep["stalls"]["select_wait"],
            "ctr_picks": rep["sched"]["picks"],
            # informational derived floats
            "link_util_p50": rep["links"]["util_p50"],
            "link_util_p95": rep["links"]["util_p95"],
            "link_util_max": rep["links"]["util_max"],
            "pick_pos_mean": rep["sched"]["pick_pos_mean"],
            "ready_depth_mean": rep["sched"]["ready_depth_mean"],
        })
    return rows
