"""Scheduler-kernel microbenchmarks: per-policy pick rate.

Times (a) the jnp reference LOD scheduler step (select + clear) at the
paper's geometry (256 PEs x 256 flag words == 8 BRAMs' worth of flags) and
larger, (b) every registered scheduler policy's full ``select`` + ``commit``
step on randomized scheduler state — the simulator's actual hot spot per
cycle — and (c) the fused Pallas scheduler kernels (``schedule_step`` and
the rotating-pointer variant) that ``OverlayConfig(engine="select")`` routes
the pick through. On this CPU container the Pallas rows run in interpret
mode (flagged ``interpret: true`` in run.py's JSON snapshot): the timing is
not physical TPU performance, but it tracks kernel-level regressions per PR
and becomes real on a TPU backend.

Output CSV: name,us_per_call,derived (derived = selects/s).
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedulers
from repro.kernels import ref


def _time(fn, *args, iters=50):
    jax.tree.leaves(fn(*args))[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / iters


def _policy_state(policy, nx, ny, words, rng, fill_rounds=16):
    """Randomized scheduler state at [nx, ny] PEs x ``words`` flag words,
    built purely through the Scheduler protocol (init + on_ready), so any
    registered policy — including future ones — benchmarks on a populated
    queue rather than its empty init state."""
    from repro.core.overlay import OverlayConfig

    L = words * 32
    g = dict(
        opcode=jnp.zeros((nx, ny, L), jnp.int32),
        fanin=jnp.full((nx, ny, L), 2, jnp.int32),
        fo_count=jnp.ones((nx, ny, L), jnp.int32),
        valid=jnp.ones((nx, ny, L), bool),
    )
    st = policy.init(g, OverlayConfig(scheduler=policy.name))
    ix = jnp.arange(nx)[:, None] * jnp.ones((1, ny), jnp.int32)
    iy = jnp.arange(ny)[None, :] * jnp.ones((nx, 1), jnp.int32)
    for _ in range(fill_rounds):
        slot = jnp.asarray(rng.integers(0, L, size=(nx, ny), dtype=np.int32))
        ready = jnp.asarray(rng.random(size=(nx, ny)) < 0.75)
        st = policy.on_ready(st, ix, iy, slot, ready)
    return jax.tree.map(jnp.asarray, st)


def run():
    rows = []
    rng = np.random.default_rng(0)
    step = jax.jit(ref.schedule_step_ref)
    for pes, words in [(256, 8), (256, 64), (256, 256), (1024, 64)]:
        bits = jnp.asarray(
            rng.integers(0, 2**32, size=(pes, words), dtype=np.uint32))
        us = _time(step, bits) * 1e6
        rows.append({
            "name": f"lod_schedule_{pes}x{words}",
            "us_per_call": round(us, 2),
            "derived": round(pes / (us * 1e-6), 0),
        })

    # Full select+commit step for every registered policy (vmapped sweep and
    # solo simulators both run exactly this per cycle).
    idle_cache = {}
    for name in sorted(schedulers.REGISTRY):
        policy = schedulers.REGISTRY[name]
        for pes, words in [(256, 8), (256, 64)]:
            side = int(math.isqrt(pes))
            st = _policy_state(policy, side, pes // side, words, rng)
            if pes not in idle_cache:
                idle_cache[pes] = jnp.ones((side, pes // side), bool)
            idle = idle_cache[pes]

            @jax.jit
            def pick(st, idle=idle, policy=policy):
                cand, have = policy.select(st, idle)
                return cand, policy.commit(st, idle & have, cand)

            us = _time(pick, st) * 1e6
            rows.append({
                "name": f"pick_{name}_{pes}x{words}",
                "us_per_call": round(us, 2),
                "derived": round(pes / (us * 1e-6), 0),
            })

    # Fused Pallas scheduler kernels (the engine="select" pick path).
    from repro.kernels import ops
    from repro.kernels.ops import _interpret

    interp = _interpret()
    for pes, words in [(256, 8), (256, 64)]:
        bits = jnp.asarray(
            rng.integers(0, 2**32, size=(pes, words), dtype=np.uint32))
        gate = jnp.asarray(rng.random(pes) < 0.75)
        ptr = jnp.asarray(
            rng.integers(0, words * 32, size=pes, dtype=np.int32))
        iters = 10 if interp else 50
        us = _time(ops.schedule_step, bits, gate, iters=iters) * 1e6
        rows.append({
            "name": f"pallas_schedule_step_{pes}x{words}",
            "us_per_call": round(us, 2),
            "derived": round(pes / (us * 1e-6), 0),
            "interpret": interp,
        })
        us = _time(ops.rotating_schedule_step, bits, ptr, gate,
                   iters=iters) * 1e6
        rows.append({
            "name": f"pallas_rotating_step_{pes}x{words}",
            "us_per_call": round(us, 2),
            "derived": round(pes / (us * 1e-6), 0),
            "interpret": interp,
        })
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
