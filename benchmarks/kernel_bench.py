"""Scheduler-kernel microbenchmarks: hierarchical LOD pick rate.

Times the jnp reference scheduler step (select + clear) at the paper's
geometry (256 PEs x 256 flag words == 8 BRAMs' worth of flags) and larger.
On TPU the Pallas kernel replaces it; interpret-mode timing is not physical,
so the CSV reports the compiled-jnp path (the simulator's actual hot spot).

Output CSV: name,us_per_call,derived (derived = selects/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=50):
    fn(*args)[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / iters


def run():
    rows = []
    rng = np.random.default_rng(0)
    step = jax.jit(ref.schedule_step_ref)
    for pes, words in [(256, 8), (256, 64), (256, 256), (1024, 64)]:
        bits = jnp.asarray(
            rng.integers(0, 2**32, size=(pes, words), dtype=np.uint32))
        us = _time(step, bits) * 1e6
        rows.append({
            "name": f"lod_schedule_{pes}x{words}",
            "us_per_call": round(us, 2),
            "derived": round(pes / (us * 1e-6), 0),
        })
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
