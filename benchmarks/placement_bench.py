"""Placement & eject-policy benchmarks (the repro.place subsystem's rows).

``placement``: fig1-family arrow-LU workloads, each simulated (ooo policy)
under four placements —

  * ``identity``  — the partitioner's default round-robin (the layout every
    other committed cycle count uses);
  * ``random``    — uniform node -> PE draw, the annealer's init/baseline;
  * ``annealed``  — the NoC-aware parallel-tempering placer from the random
    init (the tracked claim: annealed < random);
  * ``annealed_identity`` — the same placer warm-started from the identity
    layout (the "beats the default too" row).

``eject``: a congested small-grid pair quantifying the criticality-aware
W/N eject arbitration (``eject_policy="priority"``) against Hoplite's
N-first default — cycle counts and total deflections for both.

``surrogate`` (see :mod:`repro.surrogate`): two claim families —

  * *rank quality + pruning*: per fig1 workload, fit the cycle-prediction
    surrogate on ``N_TRAIN`` self-generated simulated placements, score a
    disjoint held-out set of ``N_HELD``, and report the Spearman rank
    correlation against true simulated cycles plus how close the best of the
    ``keep_top`` best-predicted candidates comes to the exhaustive best
    (simulating 8 candidates instead of 64). Spearman floor and pruning gap
    are CI-gated in ``check_bench.py``.
  * *multilevel placement at >= 100K nodes*: coarsen -> anneal -> refine
    under a fixed proposal budget on a fig1-full-family graph, versus the
    round-robin default — both cycle counts CI-gated bit-exactly (the whole
    pipeline is integer/deterministic).

``guided`` (the PR-5 tentpole): per fig1 workload, the two-stage
surrogate-guided annealer versus the plain PR-4 annealer. The guided search
runs ``GUIDED_ROUNDS_SCALE``x the proposal budget but its surrogate gate
(margin ``GUIDED_MARGIN``) rejects most proposals before the integer cost
rule, so its *full-cost evaluation* count stays under
``check_bench.GUIDED_EVAL_RATIO_MAX`` (0.5) of the unguided budget while
reaching equal-or-better simulated cycles — both the cycle counts and the
exact deterministic evaluation counters are CI-gated.

``fig1_full`` (``--full`` runs only): the ~470K-node paper-scale LU DAG,
multilevel-placed under a fixed budget and simulated against the round-robin
default — the ROADMAP's "fig1-full tracked BENCH row", cycle counts gated
bit-exactly.

Everything here is integer/deterministic (fixed PRNG keys, integer cost
annealer), so all ``cycles_*`` values are CI-gated by
``benchmarks/check_bench.py`` exactly like the fig1 rows.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import place, surrogate
from repro.core import workloads as wl
from repro.api import run as overlay_run
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory

# (row name suffix, arrow_lu args, grid, anneal budget)
PLACEMENT_WORKLOADS = [
    ("arrow_n3689", (2, 8, 6), (8, 8),
     place.AnnealConfig(replicas=8, rounds=32, steps=1024, seed=0)),
    ("arrow_n10308", (4, 8, 8), (16, 16),
     place.AnnealConfig(replicas=8, rounds=64, steps=2048, seed=0)),
]

# Congested cases for the eject-arbitration row: dense coupling on a small
# grid keeps both router inputs competing for the single eject port.
EJECT_WORKLOADS = [
    ("arrow_n9838", lambda: wl.arrow_lu_graph(2, 8, 12, seed=3), (4, 4)),
    ("banded_n16822", lambda: wl.banded_lu_graph(60, 12, seed=3), (4, 4)),
]


#: memos of (workload, grid, config) -> unguided PlacementResult / its
#: simulated SimResult: the ``placement`` and ``guided`` sections report the
#: same deterministic search, so both the anneal and its (identically
#: padded, result-invariant) cycle simulation run once.
_ANNEAL_CACHE: dict = {}
_ANNEAL_SIM_CACHE: dict = {}


def _annealed(name, g, nx, ny, acfg):
    key = (name, nx, ny, acfg)
    if key not in _ANNEAL_CACHE:
        _ANNEAL_CACHE[key] = place.anneal_placement(g, nx, ny, acfg)
    return _ANNEAL_CACHE[key]


def run_placement():
    rows = []
    for name, (blocks, bs, border), (nx, ny), acfg in PLACEMENT_WORKLOADS:
        g = wl.arrow_lu_graph(blocks, bs, border, seed=3)
        cfg = OverlayConfig(scheduler="ooo", max_cycles=4_000_000)
        t0 = time.time()
        ann = _annealed(name, g, nx, ny, acfg)
        ann_id = place.anneal_placement(
            g, nx, ny, acfg, init=place.resolve(g, nx, ny, "round_robin"))
        res = place.evaluate_placements(g, nx, ny, {
            "identity": None,
            "random": place.PlacementSpec(strategy="random", seed=acfg.seed),
            "annealed": ann.node_pe,
            "annealed_identity": ann_id.node_pe,
        }, cfgs=cfg)
        wall = time.time() - t0
        assert all(r.done for r in res.values()), name
        _ANNEAL_SIM_CACHE[(name, nx, ny, acfg)] = res["annealed"]
        rows.append({
            "name": f"placement_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: cycle-count ratio random / annealed (>1 == win)
            "derived": round(res["random"].cycles / res["annealed"].cycles, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "cycles_identity": res["identity"].cycles,
            "cycles_random": res["random"].cycles,
            "cycles_annealed": res["annealed"].cycles,
            "cycles_annealed_identity": res["annealed_identity"].cycles,
            "anneal_cost_random": ann.init_cost,
            "anneal_cost_annealed": ann.cost,
        })
    return rows


# (row name suffix, arrow_lu args, grid) for the rank-quality rows.
SURROGATE_WORKLOADS = [
    ("arrow_n3689", (2, 8, 6), (8, 8)),
    ("arrow_n10308", (4, 8, 8), (16, 16)),
]
N_TRAIN = 48      # simulated placements the surrogate fits on
N_HELD = 64       # disjoint held-out set the rank metrics score
KEEP_TOP = 8      # pruning depth: simulate only the top-k predicted

#: >= 100K-node multilevel row: fig1's 117,972-node arrow graph (cached on
#: disk so reruns skip the Python elimination loop).
MULTILEVEL_GRAPH = ("arrow_b32_s10_w8_seed3",
                    lambda: wl.arrow_lu_graph(32, 10, 8, seed=3))
MULTILEVEL_GRID = (16, 16)
MULTILEVEL_COARSE = place.AnnealConfig(replicas=8, rounds=24, steps=2048,
                                       seed=0)
MULTILEVEL_REFINE = place.AnnealConfig(replicas=4, rounds=8, steps=2048,
                                       seed=0)
MULTILEVEL_RATIO = 32


#: memo of (workload name, grid) -> fit_from_sim triple: the ``guided`` rows
#: consult the very same fitted models the ``surrogate`` rank rows report
#: on, so the N_TRAIN training simulations are spent once per workload.
_MODEL_CACHE: dict = {}


def _fitted_model(name, g, nx, ny, cfg):
    key = (name, nx, ny)
    if key not in _MODEL_CACHE:
        _MODEL_CACHE[key] = surrogate.fit_from_sim(
            g, nx, ny, cfg=cfg, n_train=N_TRAIN, seed=0)
    return _MODEL_CACHE[key]


def run_surrogate():
    rows = []
    cfg = OverlayConfig(scheduler="ooo", max_cycles=4_000_000)
    for name, args, (nx, ny) in SURROGATE_WORKLOADS:
        g = wl.arrow_lu_graph(*args, seed=3)
        t0 = time.time()
        model, _, train_cycles = _fitted_model(name, g, nx, ny, cfg)
        held = surrogate.sample_placements(g, nx, ny, N_HELD, seed=101,
                                           include_static=False)
        held_res = place.simulate_placements(g, nx, ny, list(held), cfg)
        # A truncated run would poison the CI-gated quality floors — fail
        # loudly instead (the training path inside fit_from_sim already does).
        assert all(r.done for r in held_res), name
        held_cycles = np.asarray([r.cycles for r in held_res])
        rho = surrogate.spearman(model.predict_batch(held), held_cycles)
        keep = model.rank(held)[:KEEP_TOP]
        pruned_best = int(held_cycles[keep].min())
        exhaustive_best = int(held_cycles.min())
        wall = time.time() - t0
        rows.append({
            "name": f"surrogate_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: held-out Spearman rank correlation vs true cycles
            "derived": round(rho, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "spearman": round(rho, 4),
            "n_train": N_TRAIN,
            "n_held": N_HELD,
            "keep_top": KEEP_TOP,
            # prediction-pruned search quality: best of the KEEP_TOP
            # best-predicted held-out candidates vs the exhaustive best
            # (KEEP_TOP sims instead of N_HELD — the >= 4x reduction claim).
            "pruned_best": pruned_best,
            "exhaustive_best": exhaustive_best,
            "prune_gap": round(pruned_best / exhaustive_best, 4),
            # Amortized: the fitted model is reused across searches, so a
            # pruned pass costs KEEP_TOP sims vs N_HELD exhaustive. One-shot
            # (fit included) it's N_TRAIN + KEEP_TOP — reported alongside.
            "sim_reduction": round(N_HELD / KEEP_TOP, 2),
            "sim_reduction_incl_training": round(
                N_HELD / (N_TRAIN + KEEP_TOP), 2),
            "train_cycles_min": int(train_cycles.min()),
            "train_cycles_max": int(train_cycles.max()),
        })
    return rows


def run_multilevel():
    """Coarsen -> anneal -> refine at >= 100K nodes vs the round-robin
    default, under a fixed proposal budget (cycle counts CI-gated)."""
    cache_name, builder = MULTILEVEL_GRAPH
    g = wl.cached_graph(cache_name, builder)
    nx, ny = MULTILEVEL_GRID
    t0 = time.time()
    ml = place.multilevel_anneal(
        g, nx, ny, MULTILEVEL_COARSE, ratio=MULTILEVEL_RATIO,
        refine=MULTILEVEL_REFINE)
    anneal_wall = time.time() - t0
    cfg = OverlayConfig(scheduler="ooo", max_cycles=8_000_000)
    res = place.evaluate_placements(g, nx, ny, {
        "round_robin": "round_robin",
        "multilevel": ml.node_pe,
    }, cfgs=cfg)
    wall = time.time() - t0
    assert all(r.done for r in res.values())
    acfg, rcfg = MULTILEVEL_COARSE, MULTILEVEL_REFINE
    proposals = (acfg.replicas * acfg.rounds * acfg.steps
                 + rcfg.replicas * rcfg.rounds * rcfg.steps)
    return [{
        "name": f"surrogate_multilevel_n{g.num_nodes}",
        "us_per_call": round(1e6 * wall, 1),
        # headline: cycle ratio round_robin / multilevel (>1 == win)
        "derived": round(res["round_robin"].cycles
                         / res["multilevel"].cycles, 4),
        "nodes": g.num_nodes,
        "edges": g.num_edges,
        "grid": [nx, ny],
        "clusters": ml.num_clusters,
        "coarsen_ratio": MULTILEVEL_RATIO,
        "proposal_budget": proposals,
        "wall_s": round(wall, 3),
        "anneal_wall_s": round(anneal_wall, 3),
        "cycles_round_robin": res["round_robin"].cycles,
        "cycles_multilevel": res["multilevel"].cycles,
        "cost_projected": ml.projected_cost,
        "cost_refined": ml.cost,
    }]


#: guided-annealer knobs: margin 0.0 = only predicted-non-worsening moves
#: pass the gate; the guided search gets GUIDED_ROUNDS_SCALE x the proposal
#: budget, which its ~0.2 gate pass-rate turns into well under 0.5x the
#: unguided run's full-cost evaluations (the CI-gated claim).
GUIDED_MARGIN = 0.0
GUIDED_ROUNDS_SCALE = 2


def run_guided():
    """Two-stage surrogate-guided annealing vs the plain PR-4 annealer.

    Tracked claim (CI-gated in ``check_bench.py``): per fig1 workload the
    guided search reaches ``cycles_guided <= cycles_unguided`` while its
    ``eval_ratio`` — full-cost evaluations over the unguided budget — stays
    ``<= GUIDED_EVAL_RATIO_MAX``. Both annealers and the gate are integer/
    deterministic, so every number here is bit-reproducible.
    """
    rows = []
    cfg = OverlayConfig(scheduler="ooo", max_cycles=4_000_000)
    for name, (blocks, bs, border), (nx, ny), acfg in PLACEMENT_WORKLOADS:
        g = wl.arrow_lu_graph(blocks, bs, border, seed=3)
        t0 = time.time()
        model, _, _ = _fitted_model(name, g, nx, ny, cfg)
        unguided = _annealed(name, g, nx, ny, acfg)
        gcfg = dataclasses.replace(acfg,
                                   rounds=GUIDED_ROUNDS_SCALE * acfg.rounds)
        guided = place.anneal_placement(g, nx, ny, gcfg, guide=model,
                                        guide_margin=GUIDED_MARGIN)
        # The unguided placement's simulation is reused from the placement
        # section when available (shape padding is result-invariant, so a
        # joint or solo evaluation gives identical cycles).
        unguided_sim = _ANNEAL_SIM_CACHE.get((name, nx, ny, acfg))
        to_sim = {"guided": guided.node_pe}
        if unguided_sim is None:
            to_sim["unguided"] = unguided.node_pe
        res = place.evaluate_placements(g, nx, ny, to_sim, cfgs=cfg)
        if unguided_sim is not None:
            res["unguided"] = unguided_sim
        wall = time.time() - t0
        assert all(r.done for r in res.values()), name
        unguided_evals = acfg.replicas * acfg.rounds * acfg.steps
        rows.append({
            "name": f"guided_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: full-cost evaluations vs the unguided budget (<1 ==
            # the surrogate gate is doing the screening)
            "derived": round(guided.cost_evals / unguided_evals, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "cycles_unguided": res["unguided"].cycles,
            "cycles_guided": res["guided"].cycles,
            "cost_unguided": unguided.cost,
            "cost_guided": guided.cost,
            "cost_evals": guided.cost_evals,
            "cost_evals_unguided": unguided_evals,
            "proposals_guided": guided.proposals,
            "eval_ratio": round(guided.cost_evals / unguided_evals, 4),
            "guide_margin": GUIDED_MARGIN,
            "guide_rounds": gcfg.rounds,
        })
    return rows


#: fig1-full tracked row (``--full`` only): budgeted multilevel placement +
#: simulation of the ~470K-node paper-scale LU DAG vs the round-robin
#: default. The graph itself comes from the on-disk cache
#: (``experiments/graph_cache/``, primed by CI's cache step).
FIG1_FULL_GRID = (16, 16)
FIG1_FULL_COARSE = place.AnnealConfig(replicas=8, rounds=24, steps=2048,
                                      seed=0)
FIG1_FULL_REFINE = place.AnnealConfig(replicas=4, rounds=6, steps=2048,
                                      seed=0)
#: ratio 32 (~20K clusters for ~256 PEs) rather than 64: on the
#: unstructured fig1-full LU DAG a coarser quotient can't balance the
#: wavefronts and loses to round-robin; at 32 the same budget wins ~1.2x.
FIG1_FULL_RATIO = 32


def run_fig1_full():
    g = wl.fig1_full()
    nx, ny = FIG1_FULL_GRID
    t0 = time.time()
    ml = place.multilevel_anneal(g, nx, ny, FIG1_FULL_COARSE,
                                 ratio=FIG1_FULL_RATIO,
                                 refine=FIG1_FULL_REFINE)
    anneal_wall = time.time() - t0
    cfg = OverlayConfig(scheduler="ooo", max_cycles=16_000_000)
    res = place.evaluate_placements(g, nx, ny, {
        "round_robin": "round_robin",
        "multilevel": ml.node_pe,
    }, cfgs=cfg)
    wall = time.time() - t0
    assert all(r.done for r in res.values())
    acfg, rcfg = FIG1_FULL_COARSE, FIG1_FULL_REFINE
    proposals = (acfg.replicas * acfg.rounds * acfg.steps
                 + rcfg.replicas * rcfg.rounds * rcfg.steps)
    return [{
        "name": f"fig1_full_n{g.num_nodes}",
        "us_per_call": round(1e6 * wall, 1),
        # headline: cycle ratio round_robin / multilevel (>1 == win)
        "derived": round(res["round_robin"].cycles
                         / res["multilevel"].cycles, 4),
        "nodes": g.num_nodes,
        "edges": g.num_edges,
        "grid": [nx, ny],
        "clusters": ml.num_clusters,
        "coarsen_ratio": FIG1_FULL_RATIO,
        "proposal_budget": proposals,
        "wall_s": round(wall, 3),
        "anneal_wall_s": round(anneal_wall, 3),
        "cycles_round_robin": res["round_robin"].cycles,
        "cycles_multilevel": res["multilevel"].cycles,
        "cost_projected": ml.projected_cost,
        "cost_refined": ml.cost,
    }]


def run_eject():
    rows = []
    for name, mk, (nx, ny) in EJECT_WORKLOADS:
        g = mk()
        gm = build_graph_memory(g, nx, ny, criticality_order=True)
        t0 = time.time()
        res = {}
        for pol in ("n_first", "priority"):
            res[pol] = overlay_run(gm, OverlayConfig(
                scheduler="ooo", eject_policy=pol, max_cycles=4_000_000))
            assert res[pol].done, (name, pol)
        wall = time.time() - t0
        base, prio = res["n_first"], res["priority"]
        rows.append({
            "name": f"eject_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: deflection-cycle savings of the priority pick
            "derived": round(base.cycles / prio.cycles, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "cycles_n_first": base.cycles,
            "cycles_priority": prio.cycles,
            "deflections_n_first": base.deflections,
            "deflections_priority": prio.deflections,
        })
    return rows
