"""Placement & eject-policy benchmarks (the repro.place subsystem's rows).

``placement``: fig1-family arrow-LU workloads, each simulated (ooo policy)
under four placements —

  * ``identity``  — the partitioner's default round-robin (the layout every
    other committed cycle count uses);
  * ``random``    — uniform node -> PE draw, the annealer's init/baseline;
  * ``annealed``  — the NoC-aware parallel-tempering placer from the random
    init (the tracked claim: annealed < random);
  * ``annealed_identity`` — the same placer warm-started from the identity
    layout (the "beats the default too" row).

``eject``: a congested small-grid pair quantifying the criticality-aware
W/N eject arbitration (``eject_policy="priority"``) against Hoplite's
N-first default — cycle counts and total deflections for both.

Everything here is integer/deterministic (fixed PRNG keys, integer cost
annealer), so all ``cycles_*`` values are CI-gated by
``benchmarks/check_bench.py`` exactly like the fig1 rows.
"""
from __future__ import annotations

import time

from repro import place
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig, simulate
from repro.core.partition import build_graph_memory

# (row name suffix, arrow_lu args, grid, anneal budget)
PLACEMENT_WORKLOADS = [
    ("arrow_n3689", (2, 8, 6), (8, 8),
     place.AnnealConfig(replicas=8, rounds=32, steps=1024, seed=0)),
    ("arrow_n10308", (4, 8, 8), (16, 16),
     place.AnnealConfig(replicas=8, rounds=64, steps=2048, seed=0)),
]

# Congested cases for the eject-arbitration row: dense coupling on a small
# grid keeps both router inputs competing for the single eject port.
EJECT_WORKLOADS = [
    ("arrow_n9838", lambda: wl.arrow_lu_graph(2, 8, 12, seed=3), (4, 4)),
    ("banded_n16822", lambda: wl.banded_lu_graph(60, 12, seed=3), (4, 4)),
]


def run_placement():
    rows = []
    for name, (blocks, bs, border), (nx, ny), acfg in PLACEMENT_WORKLOADS:
        g = wl.arrow_lu_graph(blocks, bs, border, seed=3)
        cfg = OverlayConfig(scheduler="ooo", max_cycles=4_000_000)
        t0 = time.time()
        ann = place.anneal_placement(g, nx, ny, acfg)
        ann_id = place.anneal_placement(
            g, nx, ny, acfg, init=place.resolve(g, nx, ny, "round_robin"))
        res = place.evaluate_placements(g, nx, ny, {
            "identity": None,
            "random": place.PlacementSpec(strategy="random", seed=acfg.seed),
            "annealed": ann.node_pe,
            "annealed_identity": ann_id.node_pe,
        }, cfgs=cfg)
        wall = time.time() - t0
        assert all(r.done for r in res.values()), name
        rows.append({
            "name": f"placement_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: cycle-count ratio random / annealed (>1 == win)
            "derived": round(res["random"].cycles / res["annealed"].cycles, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "cycles_identity": res["identity"].cycles,
            "cycles_random": res["random"].cycles,
            "cycles_annealed": res["annealed"].cycles,
            "cycles_annealed_identity": res["annealed_identity"].cycles,
            "anneal_cost_random": ann.init_cost,
            "anneal_cost_annealed": ann.cost,
        })
    return rows


def run_eject():
    rows = []
    for name, mk, (nx, ny) in EJECT_WORKLOADS:
        g = mk()
        gm = build_graph_memory(g, nx, ny, criticality_order=True)
        t0 = time.time()
        res = {}
        for pol in ("n_first", "priority"):
            res[pol] = simulate(gm, OverlayConfig(
                scheduler="ooo", eject_policy=pol, max_cycles=4_000_000))
            assert res[pol].done, (name, pol)
        wall = time.time() - t0
        base, prio = res["n_first"], res["priority"]
        rows.append({
            "name": f"eject_{name}",
            "us_per_call": round(1e6 * wall, 1),
            # headline: deflection-cycle savings of the priority pick
            "derived": round(base.cycles / prio.cycles, 4),
            "nodes": g.num_nodes,
            "edges": g.num_edges,
            "grid": [nx, ny],
            "wall_s": round(wall, 3),
            "cycles_n_first": base.cycles,
            "cycles_priority": prio.cycles,
            "deflections_n_first": base.deflections,
            "deflections_priority": prio.deflections,
        })
    return rows
