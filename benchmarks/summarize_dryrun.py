"""Render EXPERIMENTS.md tables from the dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.summarize_dryrun [--mesh single|multi|both]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 1024**3


def load(d, mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    return f"{b/1024**3:.2f}"


def table(mesh, d="experiments/dryrun"):
    rows = load(d, mesh)
    print(f"\n### Mesh `{mesh}` ({'512' if mesh == 'multi' else '256'} chips)\n")
    print("| arch | shape | status | GB/chip (args) | flops/chip | compute s | memory s | collective s | dominant | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_fail = n_skip = 0
    for r in rows:
        if r["status"] == "SKIP":
            n_skip += 1
            print(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | — | — |")
            continue
        if r["status"] != "OK":
            n_fail += 1
            print(f"| {r['arch']} | {r['shape']} | **FAIL** | — | — | — | — | — | — | — |")
            continue
        n_ok += 1
        ro = r["roofline"]
        terms = {"compute": ro["compute_s"], "memory": ro["memory_s"],
                 "collective": ro["collective_s"]}
        dom = max(terms, key=terms.get)
        args_gb = r["memory"].get("argument_size_in_bytes", 0) / 1024**3
        flops = r["hlo_walk"]["flops"]
        uf = ro.get("useful_flops_frac")
        fits = "" if args_gb <= 16 else " ⚠OOM"
        print(f"| {r['arch']} | {r['shape']} | OK | {args_gb:.2f}{fits} | "
              f"{flops:.3g} | {ro['compute_s']:.4f} | {ro['memory_s']:.4f} | "
              f"{ro['collective_s']:.4f} | {dom} | "
              f"{'' if uf is None else round(uf, 3)} |")
    print(f"\nOK={n_ok} SKIP={n_skip} FAIL={n_fail}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        table(m, args.dir)


if __name__ == "__main__":
    main()
