"""CI gate: diff a fresh BENCH_overlay.json against the committed snapshot.

Cycle counts are simulation *semantics* — for a cycle-accurate simulator they
must not regress silently. This check fails (exit 1) when any tracked cycle
count grew versus the baseline or a tracked row disappeared; cycle counts
that *shrank* are reported as improvements (update the committed snapshot to
lock them in). Wall-clock numbers are machine-dependent, so wall/throughput
deltas are printed for the log but never block (shared CI runners).

The ``surrogate`` section additionally carries two *quality floors* (also
blocking): held-out Spearman rank correlation >= SPEARMAN_FLOOR per rank row,
and the prediction-pruned best placement within PRUNE_GAP_MAX of the
exhaustive best. These are floors rather than exact diffs because the ridge
solve is float64 — integer features make it stable to reproduce, but the last
bits (and thus near-tie ranks) may differ across BLAS builds, unlike the
integer cycle counts which must match bit-exactly.

The ``guided`` section carries two more blocking relations, both on exact
deterministic integers (the guided annealer's accept rule is fully
quantized): full-cost evaluations <= GUIDED_EVAL_RATIO_MAX of the unguided
budget, and guided simulated cycles <= unguided simulated cycles.

The ``telemetry`` section's ``ctr_*`` counters (stall attribution,
deflection split, busiest-link cycles) are gated *bit-exact in both
directions*: the instrument's output on a deterministic workload must not
move at all unless the committed snapshot is updated deliberately.

The ``service`` section's ``svc_*`` counters (cached/fresh cycle pairs,
stream hit/miss/simulation counts, frontier sizes) get the same
both-direction bit-exact treatment, plus two fresh-run relations:
cached cycles == recomputed cycles per row, and the replayed stream's
hit rate >= SERVICE_HIT_RATE_FLOOR.

Usage:  python benchmarks/check_bench.py BASELINE.json FRESH.json
"""
from __future__ import annotations

import json
import sys

#: minimum held-out Spearman(predicted, simulated cycles) per surrogate row.
SPEARMAN_FLOOR = 0.8
#: max pruned_best / exhaustive_best: the top-k predicted candidates must
#: contain a placement within 5% of the exhaustive-simulation best.
PRUNE_GAP_MAX = 1.05
#: max full-cost evaluations of the guided annealer over the unguided
#: budget: the surrogate gate must screen out at least half the proposals
#: an unguided run would have cost-evaluated (exact integer counters).
GUIDED_EVAL_RATIO_MAX = 0.5
#: minimum cache hit rate on the replayed service stream: the 32-query /
#: 8-distinct stream is 75% repeats, and every repeat must answer from the
#: content-hash cache — a hit rate under 0.5 means repeat queries are
#: re-simulating.
SERVICE_HIT_RATE_FLOOR = 0.5


def _cycle_counts(bench: dict) -> dict[str, int]:
    """Flatten every tracked cycle count to {metric_name: cycles}."""
    out: dict[str, int] = {}
    flat_rows = list(bench.get("fig1", []))
    # Placement / eject / surrogate / guided / fig1_full / megakernel
    # sections carry per-row cycles_* keys like fig1 does (identity/random/
    # annealed placements; n_first/priority arbitration; multilevel and
    # guided searches; the fig1-full tracked row; the fused-chunk engine's
    # bit-exactness rows; the telemetry-on runs, whose cycles must equal the
    # untraced baseline) — all deterministic simulation semantics, all
    # blocking. (jnp_cycles_per_sec / cycles_per_sec are throughput and stay
    # informational: only the cycles_ prefix is gated.)
    for section in ("placement", "eject", "surrogate", "guided", "fig1_full",
                    "megakernel", "telemetry", "service"):
        flat_rows += bench.get(section, {}).get("rows", [])
    for row in flat_rows:
        for key, val in row.items():
            # cycles_per_sec is wall-clock throughput, not simulation
            # semantics — it belongs to the informational wall report.
            if key.startswith("cycles_") and key != "cycles_per_sec":
                out[f"{row['name']}.{key}"] = int(val)
    sweep = bench.get("policy_sweep", {})
    for row in sweep.get("schedulers", []):
        out[f"policy_sweep.cycles_{row['scheduler']}"] = int(row["cycles"])
    for row in bench.get("chunking", {}).get("rows", []):
        for sched, cycles in row.get("cycles", {}).items():
            out[f"{row['name']}.cycles_{sched}"] = int(cycles)
    return out


def _surrogate_quality(baseline: dict, fresh: dict) -> list[str]:
    """Blocking quality-floor violations in the fresh surrogate section.

    Rank rows carry no ``cycles_*`` keys, so the missing-row protection in
    the cycle diff never covers them — a baseline quality row that vanishes
    from the fresh run must fail here, or the Spearman/prune gates would
    silently disappear.
    """
    bad = []
    fresh_rows = {row["name"]: row
                  for row in fresh.get("surrogate", {}).get("rows", [])}
    for row in baseline.get("surrogate", {}).get("rows", []):
        if ("spearman" in row or "prune_gap" in row) \
                and row["name"] not in fresh_rows:
            bad.append(f"{row['name']}: quality row missing from fresh run")
    for row in fresh_rows.values():
        if "spearman" in row and row["spearman"] < SPEARMAN_FLOOR:
            bad.append(f"{row['name']}: spearman {row['spearman']} "
                       f"< floor {SPEARMAN_FLOOR}")
        if "prune_gap" in row and row["prune_gap"] > PRUNE_GAP_MAX:
            bad.append(f"{row['name']}: prune_gap {row['prune_gap']} "
                       f"> max {PRUNE_GAP_MAX} "
                       f"(pruned_best {row.get('pruned_best')} vs "
                       f"exhaustive_best {row.get('exhaustive_best')})")
    return bad


def _guided_quality(fresh: dict) -> list[str]:
    """Blocking guided-annealing floor violations in the fresh run.

    Two relations per ``guided`` row, both exact deterministic integers:
    the surrogate gate must keep full-cost evaluations at or under
    ``GUIDED_EVAL_RATIO_MAX`` of the unguided budget, and the guided search
    must reach equal-or-better simulated cycles than the unguided annealer
    of the same run. (Vanished guided rows are caught by the cycle diff —
    they carry ``cycles_*`` keys.)
    """
    bad = []
    for row in fresh.get("guided", {}).get("rows", []):
        if {"cost_evals", "cost_evals_unguided"} <= row.keys():
            # Exact integer comparison — the reported eval_ratio is rounded
            # for display and could hide a hairline violation.
            if row["cost_evals"] > GUIDED_EVAL_RATIO_MAX \
                    * row["cost_evals_unguided"]:
                bad.append(f"{row['name']}: cost_evals {row['cost_evals']} "
                           f"> {GUIDED_EVAL_RATIO_MAX} * unguided budget "
                           f"{row['cost_evals_unguided']}")
        elif "eval_ratio" in row and row["eval_ratio"] > GUIDED_EVAL_RATIO_MAX:
            bad.append(f"{row['name']}: eval_ratio {row['eval_ratio']} "
                       f"> max {GUIDED_EVAL_RATIO_MAX}")
        if {"cycles_guided", "cycles_unguided"} <= row.keys() \
                and row["cycles_guided"] > row["cycles_unguided"]:
            bad.append(f"{row['name']}: guided {row['cycles_guided']} "
                       f"cycles > unguided {row['cycles_unguided']}")
    return bad


def _telemetry_counters(baseline: dict, fresh: dict) -> list[str]:
    """Blocking instrument drift in the ``telemetry`` section.

    ``ctr_*`` keys are the telemetry traces reduced to scalars (stall
    attribution, deflection split, busiest-link cycles, pick counts) for a
    deterministic workload — the instrument's own output. Unlike cycle
    counts, *any* change (up or down) is a failure: a counter that moved
    without the simulation moving means the instrument drifted, which is a
    semantics bug even if it looks like an "improvement". Changing counter
    definitions deliberately requires updating the committed snapshot.
    """
    bad = []
    fresh_rows = {row["name"]: row
                  for row in fresh.get("telemetry", {}).get("rows", [])}
    for row in baseline.get("telemetry", {}).get("rows", []):
        new = fresh_rows.get(row["name"])
        for key, base in sorted(row.items()):
            if not key.startswith("ctr_"):
                continue
            if new is None:
                bad.append(f"{row['name']}: telemetry row missing from "
                           f"fresh run")
                break
            if key not in new:
                bad.append(f"{row['name']}.{key}: missing (was {base})")
            elif int(new[key]) != int(base):
                bad.append(f"{row['name']}.{key}: {base} -> {new[key]} "
                           f"(counters must match bit-exactly)")
    return bad


def _service_gates(baseline: dict, fresh: dict) -> list[str]:
    """Blocking placement-service contract violations.

    ``svc_*`` keys are exact deterministic integers (cached / fresh cycle
    pairs, hit/miss/simulation counters, frontier point counts) — like the
    telemetry ``ctr_*`` counters they are gated bit-exact in BOTH
    directions against the committed snapshot; a moved counter means the
    caching layer changed behavior even if cycle counts look fine. Two
    fresh-run relations also block: ``svc_cycles_cached`` must equal
    ``svc_cycles_fresh`` row by row (a cache hit must be indistinguishable
    from recomputation), and the stream ``hit_rate`` must clear
    ``SERVICE_HIT_RATE_FLOOR`` (every repeat query must actually hit).
    """
    bad = []
    fresh_rows = {row["name"]: row
                  for row in fresh.get("service", {}).get("rows", [])}
    for row in baseline.get("service", {}).get("rows", []):
        new = fresh_rows.get(row["name"])
        for key, base in sorted(row.items()):
            if not key.startswith("svc_"):
                continue
            if new is None:
                bad.append(f"{row['name']}: service row missing from "
                           f"fresh run")
                break
            if key not in new:
                bad.append(f"{row['name']}.{key}: missing (was {base})")
            elif int(new[key]) != int(base):
                bad.append(f"{row['name']}.{key}: {base} -> {new[key]} "
                           f"(service counters must match bit-exactly)")
    for row in fresh_rows.values():
        if {"svc_cycles_cached", "svc_cycles_fresh"} <= row.keys() \
                and row["svc_cycles_cached"] != row["svc_cycles_fresh"]:
            bad.append(f"{row['name']}: cached {row['svc_cycles_cached']} "
                       f"!= fresh {row['svc_cycles_fresh']} cycles")
        if "hit_rate" in row and row["hit_rate"] < SERVICE_HIT_RATE_FLOOR:
            bad.append(f"{row['name']}: hit_rate {row['hit_rate']} "
                       f"< floor {SERVICE_HIT_RATE_FLOOR}")
    return bad


def _wall_times(bench: dict) -> dict[str, float]:
    out: dict[str, float] = {}
    rows = list(bench.get("fig1", []))
    for section in ("placement", "eject", "surrogate", "guided", "fig1_full",
                    "megakernel", "telemetry", "service"):
        rows += bench.get(section, {}).get("rows", [])
    for row in rows:
        out[f"{row['name']}.wall_s"] = float(row["wall_s"])
        for key in ("cycles_per_sec", "jnp_cycles_per_sec"):
            if key in row:
                out[f"{row['name']}.{key}"] = float(row[key])
    return out


def main(baseline_path: str, fresh_path: str) -> int:
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    base_cyc = _cycle_counts(baseline)
    new_cyc = _cycle_counts(fresh)

    regressions, improvements = [], []
    for name, base in sorted(base_cyc.items()):
        if name not in new_cyc:
            regressions.append(f"{name}: missing from fresh run (was {base})")
            continue
        new = new_cyc[name]
        if new > base:
            regressions.append(f"{name}: {base} -> {new} (+{new - base})")
        elif new < base:
            improvements.append(f"{name}: {base} -> {new} ({new - base})")

    for name in sorted(set(new_cyc) - set(base_cyc)):
        print(f"NEW     {name} = {new_cyc[name]} (no baseline)")
    for line in improvements:
        print(f"BETTER  {line}")

    # Wall-clock: informational only.
    base_wall = _wall_times(baseline)
    for name, new in sorted(_wall_times(fresh).items()):
        base = base_wall.get(name)
        delta = "" if base is None else f" (baseline {base})"
        print(f"WALL    {name} = {new}{delta}")

    quality = _surrogate_quality(baseline, fresh)
    guided = _guided_quality(fresh)
    telem = _telemetry_counters(baseline, fresh)
    service = _service_gates(baseline, fresh)
    failures = regressions + quality + guided + telem + service
    if failures:
        if regressions:
            print(f"\nFAIL: {len(regressions)} cycle-count regression(s):")
            for line in regressions:
                print(f"  {line}")
        if quality:
            print(f"\nFAIL: {len(quality)} surrogate quality-floor "
                  f"violation(s):")
            for line in quality:
                print(f"  {line}")
        if guided:
            print(f"\nFAIL: {len(guided)} guided-annealing floor "
                  f"violation(s):")
            for line in guided:
                print(f"  {line}")
        if telem:
            print(f"\nFAIL: {len(telem)} telemetry counter drift(s):")
            for line in telem:
                print(f"  {line}")
        if service:
            print(f"\nFAIL: {len(service)} service contract violation(s):")
            for line in service:
                print(f"  {line}")
        return 1
    print(f"\nOK: {len(base_cyc)} tracked cycle counts, no regressions.")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
