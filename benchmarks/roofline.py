"""Roofline summary: reads the dry-run JSON records and prints per-cell
compute/memory/collective terms + dominant bottleneck (EXPERIMENTS §Roofline).

Output CSV: name,us_per_call,derived where us_per_call = dominant roofline
term (per-step, in us) and derived = "<dominant>:<useful_flops_frac>".
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load(mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        rows.append(rec)
    return rows


def run(mesh: str = "single"):
    out = []
    for rec in load(mesh):
        name = f"roofline_{rec['arch']}__{rec['shape']}"
        if rec["status"] != "OK":
            out.append({"name": name, "us_per_call": 0.0,
                        "derived": rec["status"], "rec": rec})
            continue
        r = rec["roofline"]
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        frac = r.get("useful_flops_frac")
        out.append({
            "name": name,
            "us_per_call": round(terms[dom] * 1e6, 1),
            "derived": f"{dom}:{'' if frac is None else round(frac, 3)}",
            "rec": rec,
        })
    return out


def main(mesh: str = "single"):
    print("name,us_per_call,derived")
    for r in run(mesh):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    import sys
    main("multi" if "--multi" in sys.argv else "single")
