"""Blockwise attention + SSD numerics (portable model-stack paths)."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dev dependency (pyproject [dev])
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.ref import flash_attention_ref
from repro.models.attention import blockwise_attention
from repro.models.common import ModelConfig, SSMCfg
from repro.models import ssm


def _t(x):
    return jnp.asarray(x.transpose(0, 2, 1, 3))


@pytest.mark.parametrize(
    "b,hq,hkv,tq,tkv,d,causal,kvlen,diff",
    [
        (2, 4, 2, 256, 256, 64, True, None, False),
        (2, 4, 2, 256, 256, 64, True, None, True),
        (1, 8, 8, 100, 100, 32, True, None, True),
        (2, 4, 1, 1, 512, 64, True, 300, False),
        (1, 6, 2, 64, 512, 48, True, 512, False),
        (1, 4, 4, 128, 96, 64, False, None, False),
    ],
)
def test_blockwise_matches_oracle(b, hq, hkv, tq, tkv, d, causal, kvlen, diff):
    rng = np.random.default_rng(1)
    q = rng.standard_normal((b, hq, tq, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, tkv, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, tkv, d)).astype(np.float32)
    got = blockwise_attention(
        _t(q), _t(k), _t(v), causal=causal, q_chunk=64, kv_chunk=128,
        kv_len=None if kvlen is None else jnp.int32(kvlen),
        differentiable=diff)
    kk = k[:, :, :kvlen] if kvlen else k
    vv = v[:, :, :kvlen] if kvlen else v
    want = flash_attention_ref(jnp.asarray(q), jnp.asarray(kk), jnp.asarray(vv), causal=causal)
    np.testing.assert_allclose(np.asarray(got).transpose(0, 2, 1, 3),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blockwise_gradients_match_oracle():
    rng = np.random.default_rng(2)
    b, h, t, d = 1, 2, 64, 16
    q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)

    def f_block(q, k, v):
        return blockwise_attention(q, k, v, causal=True, q_chunk=16,
                                   kv_chunk=16, differentiable=True).sum()

    def f_ref(q, k, v):
        qq, kk, vv = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
        return flash_attention_ref(qq, kk, vv, causal=True).sum()

    g1 = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-4)


def _ssm_cfg(chunk):
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=48, num_heads=0,
        num_kv_heads=0, d_ff=0, vocab_size=64, dtype="float32",
        ssm=SSMCfg(d_state=8, d_conv=4, expand=2, head_dim=12, chunk=chunk))


def test_ssd_chunked_equals_sequential_decode():
    cfg = _ssm_cfg(8)
    params = ssm.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 21, 48), jnp.float32) * 0.5
    out_seq = ssm.apply_seq(params, cfg, x)
    cache = ssm.init_cache(cfg, 2)
    outs = []
    for t in range(21):
        y, cache = ssm.apply_decode(params, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(jnp.concatenate(outs, 1)),
                               rtol=3e-4, atol=3e-4)


@given(st.sampled_from([4, 8, 16, 32]), st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_invariance(chunk, seed):
    cfg1 = _ssm_cfg(8)
    cfg2 = _ssm_cfg(chunk)
    params = ssm.init(jax.random.key(seed), cfg1)
    x = jax.random.normal(jax.random.key(seed + 1), (1, 33, 48), jnp.float32) * 0.5
    o1 = ssm.apply_seq(params, cfg1, x)
    o2 = ssm.apply_seq(params, cfg2, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)


def test_ssm_prefill_cache_matches_decode_continuation():
    from repro.models import blocks as B
    cfg = _ssm_cfg(8)
    params = {"ln1": {"w": jnp.ones((48,))}, "mamba": ssm.init(jax.random.key(0), cfg)}
    x = jax.random.normal(jax.random.key(3), (1, 16, 48), jnp.float32) * 0.3
    # full sequence through block
    aux = {"mode": "train", "positions": None, "cache": None, "cache_len": None}
    full, _ = B.block_apply(params, cfg, x, aux, "mamba")
    # prefill 12 then decode 4
    aux_p = {"mode": "prefill", "positions": None, "cache": None, "cache_len": 12}
    hp, ex = B.block_apply(params, cfg, x[:, :12], aux_p, "mamba")
    cache = ex["cache"]
    np.testing.assert_allclose(np.asarray(full[:, :12]), np.asarray(hp), rtol=2e-4, atol=2e-4)
    h = []
    for t in range(12, 16):
        aux_d = {"mode": "decode", "positions": None, "cache": cache, "cache_len": t}
        y, ex = B.block_apply(params, cfg, x[:, t:t + 1], aux_d, "mamba")
        cache = ex["cache"]
        h.append(y)
    np.testing.assert_allclose(np.asarray(full[:, 12:]),
                               np.asarray(jnp.concatenate(h, 1)), rtol=3e-4, atol=3e-4)
