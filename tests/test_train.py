"""Training substrate: loss decrease, losses oracle, optimizer math,
schedules, grad accumulation parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticCopyTask, SyntheticZipfLM
from repro.optim import AdamW, cosine_schedule, wsd_schedule
from repro.train.losses import chunked_softmax_xent
from repro.train.steps import init_train_state, make_train_step


def test_chunked_xent_matches_direct():
    rng = np.random.default_rng(0)
    b, t, d, v = 2, 17, 8, 11
    h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, t)), jnp.float32)
    loss, m = chunked_softmax_xent(h, head, labels, mask, chunk=5)
    logits = h @ head
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    want = (nll * mask).sum() / mask.sum()
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)


def test_chunked_xent_padded_vocab_mask():
    rng = np.random.default_rng(1)
    b, t, d, v, vp = 1, 8, 4, 6, 10
    h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
    head_p = jnp.asarray(rng.standard_normal((d, vp)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
    loss_p, _ = chunked_softmax_xent(h, head_p, labels, chunk=4, valid_vocab=v)
    loss_ref, _ = chunked_softmax_xent(h, head_p[:, :v], labels, chunk=4)
    np.testing.assert_allclose(float(loss_p), float(loss_ref), rtol=1e-5)


def test_adamw_step_matches_manual():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=1e9, master_weights=True)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = opt.init(p)
    newp, st, m = opt.update(g, st, p)
    mm = 0.1 * 0.5
    vv = 0.01 * 0.25
    upd = (mm / (1 - 0.9)) / (np.sqrt(vv / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]),
                               np.asarray(p["w"]) - 0.1 * upd, rtol=1e-6)


def test_grad_clip():
    opt = AdamW(lr=0.0, clip_norm=1.0, master_weights=False, weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = opt.init(p)
    _, _, m = opt.update(g, st, p)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_wsd_schedule_phases():
    lr = wsd_schedule(1.0, warmup=10, stable=20, decay=10, final_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(25)) == pytest.approx(1.0)
    assert float(lr(40)) == pytest.approx(0.1, rel=1e-3)
    lrc = cosine_schedule(1.0, warmup=5, total=50)
    assert float(lrc(5)) == pytest.approx(1.0)
    assert float(lrc(50)) == pytest.approx(0.1, rel=1e-2)


def test_loss_decreases_quickly():
    cfg = get_config("qwen2-0.5b", smoke=True)
    opt = AdamW(lr=wsd_schedule(1e-2, 10, 1000, 100), weight_decay=0.01)
    state = init_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    ds = SyntheticCopyTask(cfg.vocab_size, batch=16, seq=32, seed=0)
    losses = []
    for i in range(60):
        state, m = step(state, {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9


def test_grad_accum_close_to_full_batch():
    cfg = get_config("minicpm-2b", smoke=True)
    opt = AdamW(lr=1e-3, master_weights=False)
    ds = SyntheticZipfLM(cfg.vocab_size, batch=8, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    s0 = init_train_state(jax.random.key(0), cfg, opt)
    s1 = init_train_state(jax.random.key(0), cfg, opt)
    full = jax.jit(make_train_step(cfg, opt, grad_accum=1))
    acc = jax.jit(make_train_step(cfg, opt, grad_accum=2))
    s0, m0 = full(s0, batch)
    s1, m1 = acc(s1, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s0["params"]), jax.tree.leaves(s1["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_data_determinism_and_host_sharding():
    d1 = SyntheticCopyTask(100, batch=8, seq=16, seed=3)
    d2 = SyntheticCopyTask(100, batch=8, seq=16, seed=3)
    b1, b2 = d1.batch_at(7), d2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    h0 = SyntheticCopyTask(100, batch=8, seq=16, seed=3, num_hosts=2, host_id=0)
    h1 = SyntheticCopyTask(100, batch=8, seq=16, seed=3, num_hosts=2, host_id=1)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])
