"""Serving correctness: prefill + token-by-token decode must reproduce the
teacher-forced forward logits (MoE archs tested with no-drop capacity, since
capacity cuts are sequence-length dependent by design)."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm

TOL = dict(rtol=3e-4, atol=3e-4)


def _nodrop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _nodrop(get_config(arch, smoke=True))
    params = lm.init(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)
    b, t, p = 2, 20, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    if cfg.encdec is not None:
        frames = jnp.asarray(rng.standard_normal((b, 24, cfg.d_model)), jnp.float32)
        h, _ = lm.forward_encdec(params, cfg, frames, toks)
        full = lm.logits_fn(params, cfg, h)
        cache = lm.encdec_init_cache(cfg, b, max_dec_len=t, enc_len=24)
        lg, cache = lm.prefill_encdec(params, cfg, frames, toks[:, :p], cache)
        np.testing.assert_allclose(lg, full[:, p - 1], **TOL)
        for i in range(p, t):
            lg, cache = lm.decode_step_encdec(params, cfg, toks[:, i], cache, jnp.int32(i))
            np.testing.assert_allclose(lg, full[:, i], **TOL)
    else:
        h, _ = lm.forward(params, cfg, tokens=toks)
        full = lm.logits_fn(params, cfg, h)
        cache = lm.init_cache(cfg, b, max_len=t)
        lg, cache = lm.prefill(params, cfg, tokens=toks[:, :p], cache=cache)
        np.testing.assert_allclose(lg, full[:, p - 1], **TOL)
        for i in range(p, t):
            lg, cache = lm.decode_step(params, cfg, toks[:, i], cache, jnp.int32(i))
            np.testing.assert_allclose(lg, full[:, i], **TOL)


def test_greedy_generation_runs():
    from repro.train.steps import make_decode_step, make_prefill_step
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = lm.init(jax.random.key(0), cfg)
    b, p, gen = 2, 8, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)
    cache = lm.init_cache(cfg, b, max_len=p + gen)
    prefill = make_prefill_step(cfg)
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [cur]
    for i in range(gen - 1):
        cur, _, cache = decode(params, cur, cache, jnp.int32(p + i))
        outs.append(cur)
    seq = jnp.stack(outs, 1)
    assert seq.shape == (b, gen)
    assert bool((seq >= 0).all()) and bool((seq < cfg.vocab_size).all())
