"""Placement subsystem (repro.place): identity bit-exactness across every
scheduler policy, cost-model/vmap consistency, annealer determinism under a
fixed PRNG key, annealed-beats-random on the fig1 workload family, and the
backend/device-count-aware check_every autotune."""
import numpy as np
import pytest

from repro import place
from repro.core import schedulers
from repro.core import workloads as wl
from repro.core.overlay import (
    OverlayConfig, resolve_check_every, simulate, simulate_batch,
)
from repro.core.partition import build_graph_memory

ALL_POLICIES = sorted(schedulers.REGISTRY)

#: small fig1-family graph: fast, but structured like the paper's workloads
G = wl.arrow_lu_graph(3, 6, 4, seed=5)

#: quick annealer budget for tests (the benchmarks use deeper ones)
ACFG = place.AnnealConfig(replicas=6, rounds=10, steps=192, seed=0)


def _stats(r):
    return (r.done, r.cycles, r.deflections, r.busy_cycles, r.delivered)


# ---------------------------------------------------------------------------
# Identity placement == the legacy direct-GraphMemory path, bit-exactly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched", ALL_POLICIES)
def test_identity_placement_bit_identical(sched):
    wants = schedulers.get(sched).wants_criticality_order
    cfg = OverlayConfig(scheduler=sched, max_cycles=500_000)
    ref = simulate(build_graph_memory(G, 4, 4, criticality_order=wants), cfg)
    r = simulate(G, cfg, nx=4, ny=4)
    assert _stats(r) == _stats(ref), sched
    np.testing.assert_array_equal(r.values, ref.values)


def test_explicit_array_matches_strategy_name():
    node_pe = place.resolve(G, 4, 4, "clustered")
    via_array = build_graph_memory(G, 4, 4, placement=node_pe)
    via_name = build_graph_memory(G, 4, 4, placement="clustered")
    for field in ("opcode", "fanin", "fo_base", "fo_count", "valid",
                  "e_dst_pe", "e_dst_slot", "e_dst_opidx",
                  "node_pe", "node_slot"):
        np.testing.assert_array_equal(getattr(via_array, field),
                                      getattr(via_name, field), err_msg=field)


def test_assign_slots_is_the_partition_layout():
    from repro.core.criticality import criticality

    gm = build_graph_memory(G, 4, 4, criticality_order=True)
    node_slot, local_counts = place.assign_slots(
        gm.node_pe, criticality(G, "height"), 16)
    np.testing.assert_array_equal(node_slot, gm.node_slot)
    np.testing.assert_array_equal(local_counts, gm.local_counts)


def test_bad_placements_rejected():
    with pytest.raises(ValueError, match="unknown placement strategy"):
        place.PlacementSpec(strategy="teleport")
    with pytest.raises(ValueError, match="outside the"):
        build_graph_memory(G, 2, 2, placement=np.full(G.num_nodes, 99))
    with pytest.raises(ValueError, match="node->PE"):
        build_graph_memory(G, 2, 2, placement=np.zeros(3, np.int32))
    with pytest.raises(TypeError):
        OverlayConfig(placement=3.14)


def test_simulate_batch_requires_uniform_placement():
    cfgs = [OverlayConfig(), OverlayConfig(placement="clustered")]
    with pytest.raises(ValueError, match="uniform placement"):
        simulate_batch(G, cfgs, nx=4, ny=4)


# ---------------------------------------------------------------------------
# Cost model: vmapped batch == per-candidate scoring; torus is one-way.
# ---------------------------------------------------------------------------

def test_torus_hops_unidirectional():
    nx = ny = 4
    # PE 0 -> its east neighbour (pe = x*ny + y, so +ny is one X hop).
    assert int(place.torus_hops(0, ny, nx, ny)) == 1
    # ... and back the "short way" must wrap the whole ring.
    assert int(place.torus_hops(ny, 0, nx, ny)) == nx - 1
    assert int(place.torus_hops(5, 5, nx, ny)) == 0


def test_batch_cost_matches_single():
    model = place.build_cost_model(G, 4, 4)
    rng = np.random.default_rng(0)
    cands = rng.integers(0, 16, size=(5, G.num_nodes)).astype(np.int32)
    batch = np.asarray(model.batch_cost(cands))
    solo = np.asarray([int(model.cost(c)) for c in cands])
    np.testing.assert_array_equal(batch, solo)
    assert batch.dtype == np.int64


def test_cost_prefers_local_edges():
    model = place.build_cost_model(G, 4, 4)
    all_one_pe = np.zeros(G.num_nodes, np.int32)       # zero traffic, max pile
    spread = place.resolve(G, 4, 4, "round_robin")
    assert int(model.traffic(all_one_pe)) == 0
    assert int(model.pressure(spread)) < int(model.pressure(all_one_pe))


# ---------------------------------------------------------------------------
# Annealer: deterministic, never worse than its init, beats random on cycles.
# ---------------------------------------------------------------------------

def test_anneal_deterministic_under_fixed_key():
    r1 = place.anneal_placement(G, 4, 4, ACFG)
    r2 = place.anneal_placement(G, 4, 4, ACFG)
    np.testing.assert_array_equal(r1.node_pe, r2.node_pe)
    assert r1.cost == r2.cost and r1.init_cost == r2.init_cost


def test_anneal_seeds_decorrelate():
    r1 = place.anneal_placement(G, 4, 4, ACFG)
    r2 = place.anneal_placement(
        G, 4, 4, place.AnnealConfig(replicas=ACFG.replicas, rounds=ACFG.rounds,
                                    steps=ACFG.steps, seed=7))
    assert (r1.node_pe != r2.node_pe).any()


def test_anneal_cost_never_worse_than_init():
    res = place.anneal_placement(G, 4, 4, ACFG)
    assert res.cost <= res.init_cost
    model = place.build_cost_model(G, 4, 4)
    assert int(model.cost(res.node_pe)) == res.cost  # reported == rescored


@pytest.mark.parametrize("blocks,bs,border,grid", [
    (3, 6, 4, (4, 4)),
    (2, 8, 6, (8, 8)),
])
def test_annealed_never_increases_cycles_vs_random(blocks, bs, border, grid):
    g = wl.arrow_lu_graph(blocks, bs, border, seed=3)
    nx, ny = grid
    ann = place.anneal_placement(g, nx, ny, ACFG)
    res = place.evaluate_placements(g, nx, ny, {
        "random": place.PlacementSpec(strategy="random", seed=ACFG.seed),
        "annealed": ann.node_pe,
    }, cfgs=OverlayConfig(max_cycles=500_000))
    assert res["random"].done and res["annealed"].done
    assert res["annealed"].cycles <= res["random"].cycles


def test_evaluate_placements_honors_spec_metric():
    # Slot ordering must follow each spec's own criticality metric (the
    # uniform-shape packing path must not silently fall back to "height").
    spec = place.PlacementSpec(strategy="clustered", metric="neg_slack")
    cfg = OverlayConfig(max_cycles=500_000)
    res = place.evaluate_placements(G, 4, 4, {"s": spec}, cfgs=cfg)["s"]
    ref = simulate(place.graph_memory(G, 4, 4, spec), cfg)
    assert _stats(res) == _stats(ref)
    np.testing.assert_array_equal(res.values, ref.values)


def test_evaluate_placements_sharded_matches_single_device():
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    cfgs = [OverlayConfig(max_cycles=500_000),
            OverlayConfig(select_latency=2, max_cycles=500_000)]
    pls = {"identity": None, "clustered": "clustered"}
    # Mixed layout preferences in one sweep would silently skew non-first
    # schedulers (one packed memory per placement) — must be rejected.
    with pytest.raises(ValueError, match="wants_criticality_order"):
        place.evaluate_placements(
            G, 4, 4, pls,
            cfgs=cfgs + [OverlayConfig(scheduler="inorder",
                                       max_cycles=500_000)])
    solo = place.evaluate_placements(G, 4, 4, pls, cfgs=cfgs)
    shard = place.evaluate_placements(G, 4, 4, pls, cfgs=cfgs, mesh=mesh)
    for name in pls:
        for a, b in zip(solo[name], shard[name]):
            assert _stats(a) == _stats(b), name
            np.testing.assert_array_equal(a.values, b.values)


def test_spec_threading_through_overlay_config():
    spec = place.PlacementSpec(strategy="anneal", anneal=ACFG)
    cfg = OverlayConfig(placement=spec, max_cycles=500_000)
    r = simulate(G, cfg, nx=4, ny=4)
    ref = simulate(
        build_graph_memory(G, 4, 4,
                           placement=place.anneal_placement(G, 4, 4, ACFG).node_pe),
        OverlayConfig(max_cycles=500_000))
    assert _stats(r) == _stats(ref)
    np.testing.assert_array_equal(r.values, ref.values)


# ---------------------------------------------------------------------------
# check_every autotune: keyed on backend + device count, not just size.
# ---------------------------------------------------------------------------

def test_check_every_keyed_on_backend_and_devices():
    cfg = OverlayConfig()
    # CPU, single device: the graph-size table (seed behavior, unchanged).
    assert resolve_check_every(cfg, 16, 16, 16, backend="cpu", num_devices=1) == 8
    assert resolve_check_every(cfg, 16, 16, 64, backend="cpu", num_devices=1) == 16
    assert resolve_check_every(cfg, 32, 32, 256, backend="cpu", num_devices=1) == 32
    # Multi-device mesh (e.g. the 8-fake-device CPU mesh): the chunk
    # amortizes cross-shard collectives, so depth wins at every size.
    for devices in (2, 8, 32):
        assert resolve_check_every(
            cfg, 16, 16, 16, backend="cpu", num_devices=devices) == 32
    # Single-device TPU: at least 16 even for small graphs.
    assert resolve_check_every(cfg, 16, 16, 16, backend="tpu", num_devices=1) == 16
    assert resolve_check_every(cfg, 32, 32, 256, backend="tpu", num_devices=1) == 32
    # Explicit check_every always wins.
    assert resolve_check_every(OverlayConfig(check_every=5), 16, 16, 16,
                               backend="tpu", num_devices=8) == 5
