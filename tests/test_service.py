"""repro.service + repro.run: content-hash caching (cross-process stable,
zero-simulation hits counter-asserted), vmapped multi-query anneal parity,
Pareto frontier determinism, the mixed-graph shape-class fix, and the
``repro.run`` dispatcher's bit-parity with all four legacy entry points."""
import dataclasses
import subprocess
import sys
import warnings

import numpy as np
import pytest

import repro
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory
from repro.place.spec import IDENTITY, PlacementSpec
from repro.service import (PlacementQuery, PlacementService, ResultCache,
                           explore, graph_digest, query_key)

G = wl.arrow_lu_graph(2, 6, 4, seed=1)
NX = NY = 4
CFG = OverlayConfig(placement="anneal", max_cycles=200_000)


def _q(graph=G, nx=NX, ny=NY, objective="cycles", budget=2048, cfg=CFG):
    return PlacementQuery(graph=graph, nx=nx, ny=ny, objective=objective,
                          budget=budget, cfg=cfg)


# ---------------------------------------------------------------------------
# Content hashing
# ---------------------------------------------------------------------------

KEY_SCRIPT = r"""
import sys; sys.path.insert(0, "src")
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.service import query_key
g = wl.arrow_lu_graph(2, 6, 4, seed=1)
cfg = OverlayConfig(placement="anneal", max_cycles=200_000)
print(query_key(g, 4, 4, cfg, "cycles"))
"""


def test_query_key_stable_across_processes():
    # No Python hash() anywhere in the pipeline: a fresh interpreter (own
    # PYTHONHASHSEED) must derive the identical int64 key.
    local = query_key(G, NX, NY, CFG, "cycles")
    out = subprocess.run([sys.executable, "-c", KEY_SCRIPT],
                         capture_output=True, text=True, check=True)
    assert int(out.stdout.strip()) == local
    assert isinstance(local, int) and np.int64(local) == local


def test_query_key_discriminates():
    base = query_key(G, NX, NY, CFG, "cycles")
    perturbed = dataclasses.replace(
        G, initial_values=G.initial_values + np.float32(1))
    assert query_key(perturbed, NX, NY, CFG, "cycles") != base
    assert query_key(G, NX, 8, CFG, "cycles") != base
    assert query_key(G, NX, NY, CFG, "cost") != base
    assert query_key(
        G, NX, NY, dataclasses.replace(CFG, scheduler="inorder"),
        "cycles") != base
    assert query_key(
        G, NX, NY, dataclasses.replace(CFG, placement="identity"),
        "cycles") != base
    assert graph_digest(perturbed) != graph_digest(G)


def test_query_key_ignores_execution_only_knobs():
    # engine and check_every change HOW the engine runs, never the bits it
    # produces — configs differing only there must share one cache entry.
    base = query_key(G, NX, NY, CFG, "cycles")
    for variant in (dataclasses.replace(CFG, engine="select"),
                    dataclasses.replace(CFG, engine="megakernel"),
                    dataclasses.replace(CFG, check_every=1)):
        assert query_key(G, NX, NY, variant, "cycles") == base


# ---------------------------------------------------------------------------
# The cache contract: hits are free and bit-exact
# ---------------------------------------------------------------------------

def test_cache_hit_zero_simulations_bit_exact():
    svc = PlacementService()
    first = svc.query(_q())
    assert not first.cached and first.cycles is not None
    sims = svc.counters["simulations"]
    second = svc.query(_q())
    assert second.cached
    assert svc.counters["simulations"] == sims, "cache hit ran a simulation"
    assert second.cycles == first.cycles
    assert second.stats == first.stats
    np.testing.assert_array_equal(second.node_pe, first.node_pe)
    rep = svc.report()
    assert rep["cache_hits"] == 1 and rep["cache_misses"] == 1


def test_within_batch_duplicates_resolved_once():
    svc = PlacementService()
    a, b = svc.run_batch([_q(), _q()])
    assert a.key == b.key
    assert svc.counters["simulations"] == 1
    assert a.cycles == b.cycles
    np.testing.assert_array_equal(a.node_pe, b.node_pe)


def test_cost_objective_runs_zero_simulations():
    svc = PlacementService()
    r = svc.query(_q(objective="cost"))
    assert svc.counters["simulations"] == 0
    assert r.cycles is None and isinstance(r.cost, int)


def test_cache_disk_persistence(tmp_path):
    d = str(tmp_path / "svc")
    a = PlacementService(cache_dir=d).query(_q())
    svc2 = PlacementService(cache_dir=d)
    b = svc2.query(_q())
    assert b.cached and svc2.counters["simulations"] == 0
    assert svc2.cache.disk_hits == 1
    assert b.cycles == a.cycles and b.stats == a.stats
    np.testing.assert_array_equal(b.node_pe, a.node_pe)


def test_cache_lru_eviction_counted():
    cache = ResultCache(capacity=2)
    svc = PlacementService(cache=cache)
    for b in (2, 3, 4):
        svc.query(_q(graph=wl.arrow_lu_graph(b, 6, 4, seed=1)))
    assert cache.evictions == 1
    # evicted first entry misses again
    r = svc.query(_q(graph=wl.arrow_lu_graph(2, 6, 4, seed=1)))
    assert not r.cached


# ---------------------------------------------------------------------------
# Batched anneal fan-out == solo, row for row
# ---------------------------------------------------------------------------

def test_batched_anneal_rows_match_solo_queries():
    seeds = (0, 1, 2)

    def mk(s):
        return _q(cfg=OverlayConfig(
            placement=PlacementSpec(strategy="anneal", seed=s),
            max_cycles=200_000))

    svc = PlacementService()
    batched = svc.run_batch([mk(s) for s in seeds])
    assert svc.counters["batched_anneals"] == len(seeds)
    for s, b in zip(seeds, batched):
        solo = PlacementService().query(mk(s))
        np.testing.assert_array_equal(b.node_pe, solo.node_pe), s
        assert b.cycles == solo.cycles, s
        assert b.stats == solo.stats, s


# ---------------------------------------------------------------------------
# Explorer
# ---------------------------------------------------------------------------

def test_pareto_frontier_deterministic_and_nondominated():
    space = {"grid": ((2, 2), (4, 4)), "placement": ("identity", "anneal")}
    rec1 = explore(G, space=space, budget=2048, max_cycles=200_000)
    rec2 = explore(G, space=space, budget=2048, max_cycles=200_000)
    assert rec1["frontier"] == rec2["frontier"]
    assert rec1["points"] == rec2["points"]
    front = rec1["frontier"]
    assert front, "empty frontier"
    for p in front:
        assert not any(q["cycles"] <= p["cycles"]
                       and q["num_pes"] <= p["num_pes"] and q is not p
                       and (q["cycles"] < p["cycles"]
                            or q["num_pes"] < p["num_pes"])
                       for q in rec1["points"]), p["name"]


def test_explore_shares_service_cache():
    svc = PlacementService()
    space = {"scheduler": ("ooo",), "eject_policy": ("n_first",),
             "grid": ((2, 2),), "placement": ("identity",)}
    explore(G, space=space, service=svc)
    rec = explore(G, space=space, service=svc)
    assert all(p["cached"] for p in rec["points"])
    assert svc.counters["simulations"] == 1


# ---------------------------------------------------------------------------
# Mixed-graph shape classes: one jit entry per padded shape class
# ---------------------------------------------------------------------------

def test_mixed_graph_batch_compiles_once():
    from repro import place
    from repro.core.overlay import _run_batch_jit

    cfg = OverlayConfig(max_cycles=500_000)
    g_small = wl.arrow_lu_graph(2, 6, 4, seed=1)
    g_big = wl.arrow_lu_graph(3, 6, 4, seed=2)
    pes = [(g, place.resolve(g, NX, NY, "identity"))
           for g in (g_small, g_big)]
    lmax, emax = place.shape_class(pes, NX, NY)
    before = _run_batch_jit._cache_size()
    results = {}
    for g, pe in pes:
        res = place.evaluate_placements(
            g, NX, NY, {"identity": pe}, cfgs=cfg,
            min_lmax=lmax, min_emax=emax)
        results[g.num_nodes] = res["identity"].cycles
    assert _run_batch_jit._cache_size() - before <= 1, (
        "mixed-size graphs retraced the batched engine")
    # padding to the joint class must not change the answers
    for g, pe in pes:
        ref = place.evaluate_placements(g, NX, NY, {"identity": pe},
                                        cfgs=cfg)
        assert ref["identity"].cycles == results[g.num_nodes]


def test_service_stream_hit_rate():
    stream = wl.service_stream(n_queries=32, distinct=8, seed=0)
    names = [n for n, _ in stream]
    assert len(stream) == 32 and len(set(names)) == 8
    # every distinct graph appears, and >= 50% of the stream is repeats
    assert (len(stream) - len(set(names))) / len(stream) >= 0.5
    # deterministic replay
    again = wl.service_stream(n_queries=32, distinct=8, seed=0)
    assert names == [n for n, _ in again]
    for (_, a), (_, b) in zip(stream, again):
        np.testing.assert_array_equal(a.opcode, b.opcode)


# ---------------------------------------------------------------------------
# repro.run: one front door, four legacy spellings
# ---------------------------------------------------------------------------

POLICIES = ("ooo", "inorder")


def _mesh11():
    import jax

    return jax.make_mesh((1, 1), ("data", "model"))


def _stats(r):
    return (int(r.cycles), bool(r.done), int(r.delivered),
            int(r.deflections), int(r.busy_cycles))


@pytest.mark.parametrize("sched", POLICIES)
def test_run_matches_all_legacy_entry_points(sched):
    from repro.core import distributed, overlay

    cfg = OverlayConfig(scheduler=sched, max_cycles=200_000)
    gm = build_graph_memory(G, 2, 2, criticality_order=True)
    ref = repro.run(gm, cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # wrappers must warn; run must not
        repro.run(gm, cfg)
        repro.run(gm, batch=[cfg])
    with pytest.deprecated_call():
        legacy = overlay.simulate(gm, cfg)
    assert _stats(legacy) == _stats(ref)
    with pytest.deprecated_call():
        legacy_b = overlay.simulate_batch(gm, [cfg])[0]
    assert _stats(legacy_b) == _stats(repro.run(gm, batch=[cfg])[0])
    np.testing.assert_array_equal(legacy.values, ref.values)

    mesh = _mesh11()
    run_sh = repro.run(gm, cfg, mesh=mesh)
    with pytest.deprecated_call():
        legacy_sh = distributed.simulate_sharded(gm, mesh, cfg)
    assert _stats(legacy_sh) == _stats(run_sh) == _stats(ref)
    run_bsh = repro.run(gm, batch=[cfg], mesh=mesh)[0]
    with pytest.deprecated_call():
        legacy_bsh = distributed.simulate_batch_sharded(gm, mesh, [cfg])[0]
    assert _stats(legacy_bsh) == _stats(run_bsh) == _stats(ref)


def test_run_accepts_raw_graph_with_grid():
    cfg = OverlayConfig(max_cycles=200_000)
    r = repro.run(G, cfg, nx=2, ny=2)
    gm = build_graph_memory(G, 2, 2, criticality_order=True)
    assert _stats(repro.run(gm, cfg)) == _stats(r)


def test_run_rejects_cfg_and_batch():
    gm = build_graph_memory(G, 2, 2)
    with pytest.raises(ValueError, match="either"):
        repro.run(gm, OverlayConfig(), batch=[OverlayConfig()])


# ---------------------------------------------------------------------------
# Uniform placement resolution (the use_pallas shim is gone; resolve() is
# the single normalization point)
# ---------------------------------------------------------------------------

def test_config_placement_normalized():
    from repro.place.spec import resolve

    assert OverlayConfig().placement is IDENTITY
    spec = OverlayConfig(placement="anneal").placement
    assert isinstance(spec, PlacementSpec) and spec.strategy == "anneal"
    explicit = PlacementSpec(strategy="anneal", seed=7)
    assert OverlayConfig(placement=explicit).placement is explicit
    assert resolve(None) is IDENTITY
    with pytest.raises(TypeError):
        resolve(42)
    with pytest.raises(TypeError):
        OverlayConfig(use_pallas=True)
