"""Overlay simulator: functional correctness (== topological reference eval),
deadlock freedom, packet conservation — both schedulers, several grids.
These are the system's core invariants (hypothesis-driven)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro.core.overlay import OverlayConfig, simulate
from repro.core.partition import build_graph_memory


def _run(g, nx, ny, sched, **kw):
    gm = build_graph_memory(g, nx, ny, criticality_order=(sched == "ooo"))
    cfg = OverlayConfig(scheduler=sched, max_cycles=500_000, **kw)
    return simulate(gm, cfg), gm


@pytest.mark.parametrize("sched", ["ooo", "inorder"])
@pytest.mark.parametrize("grid", [(1, 1), (2, 2), (4, 4), (2, 4)])
def test_overlay_matches_reference(sched, grid):
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    ref = reference_evaluate(g)
    r, _ = _run(g, *grid, sched)
    assert r.done, "simulation did not terminate"
    np.testing.assert_allclose(r.values, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sched", ["ooo", "inorder"])
def test_packet_conservation(sched):
    g = wl.layered_dag(6, 8, seed=2)
    r, _ = _run(g, 2, 2, sched)
    # every edge is delivered exactly once
    assert r.delivered == g.num_edges
    assert r.busy_cycles == int((g.fanin_count() > 0).sum())


@given(st.integers(10, 90), st.integers(0, 5_000),
       st.sampled_from(["ooo", "inorder"]))
@settings(max_examples=10, deadline=None)
def test_random_dags_execute_correctly(n, seed, sched):
    g = wl.random_dag(n, seed=seed)
    ref = reference_evaluate(g)
    r, _ = _run(g, 2, 2, sched)
    assert r.done
    np.testing.assert_allclose(r.values, ref, rtol=1e-5, atol=1e-5)


def test_ooo_equals_inorder_functionally():
    g = wl.sparse_lu_graph(10, 0.35, seed=7)
    r1, _ = _run(g, 2, 2, "ooo")
    r2, _ = _run(g, 2, 2, "inorder")
    np.testing.assert_allclose(r1.values, r2.values, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("sched", ["ooo", "inorder"])
def test_priority_eject_matches_reference(sched):
    # Criticality-aware W/N eject arbitration changes packet timing, never
    # packet semantics: values still match the functional oracle and every
    # edge is still delivered exactly once.
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    ref = reference_evaluate(g)
    r, _ = _run(g, 4, 4, sched, eject_policy="priority")
    assert r.done
    np.testing.assert_allclose(r.values, ref, rtol=1e-5, atol=1e-5)
    assert r.delivered == g.num_edges


def test_priority_eject_irrelevant_with_dual_ports():
    # With eject_capacity=2 there is no eject contention to arbitrate, so
    # both policies must be cycle-identical.
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    a, _ = _run(g, 2, 2, "ooo", eject_capacity=2)
    b, _ = _run(g, 2, 2, "ooo", eject_capacity=2, eject_policy="priority")
    assert (a.cycles, a.deflections, a.busy_cycles) == \
        (b.cycles, b.deflections, b.busy_cycles)
    np.testing.assert_array_equal(a.values, b.values)


def test_select_latency_slows_down():
    g = wl.reduction_tree(64)
    fast, _ = _run(g, 2, 2, "ooo")
    slow, _ = _run(g, 2, 2, "ooo", select_latency=4)
    assert slow.cycles > fast.cycles


def test_criticality_order_layout():
    from repro.core.criticality import height
    g = wl.arrow_lu_graph(2, 5, 3, seed=1)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    h = height(g)
    # within each PE, slots are in decreasing criticality order
    for pe in range(4):
        nodes = np.where(gm.node_pe == pe)[0]
        slots = gm.node_slot[nodes]
        order = nodes[np.argsort(slots)]
        hs = h[order]
        assert (np.diff(hs) <= 0).all()


def test_single_pe_is_serial():
    g = wl.chain(20)
    r, _ = _run(g, 1, 1, "ooo")
    # a chain on one PE: >= 2 cycles per node (fire + packet)
    assert r.cycles >= 2 * 20
