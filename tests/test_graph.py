"""Dataflow-graph IR: construction, validation, reference eval, criticality."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import workloads as wl
from repro.core.criticality import asap_levels, criticality, height, slack
from repro.core.graph import (
    OP_ADD, OP_MUL, GraphBuilder, reference_evaluate,
)


def test_builder_and_reference_eval():
    b = GraphBuilder()
    x = b.input(2.0)
    y = b.input(3.0)
    s = b.op(OP_ADD, x, y)      # 5
    p = b.op(OP_MUL, s, y)      # 15
    g = b.build()
    vals = reference_evaluate(g)
    assert vals[s] == pytest.approx(5.0)
    assert vals[p] == pytest.approx(15.0)


def test_validation_catches_missing_operand():
    b = GraphBuilder()
    x = b.input(1.0)
    b._op.append(OP_ADD)  # corrupt: op node with no edges
    b._init.append(0.0)
    with pytest.raises(ValueError):
        b.build()


def test_topological_order_covers_all():
    g = wl.random_dag(200, seed=0)
    order = g.topological_order()
    assert sorted(order) == list(range(g.num_nodes))


def test_height_and_slack_invariants():
    g = wl.random_dag(150, seed=1)
    h = height(g)
    s = slack(g)
    a = asap_levels(g)
    assert (s >= 0).all()
    assert (h >= 0).all()
    # critical path nodes have zero slack
    assert (s == 0).sum() >= 1
    # height decreases along edges
    ptr, dst = g.fanout_ptr, g.fanout_dst
    for v in range(g.num_nodes):
        for u in dst[ptr[v]:ptr[v + 1]]:
            assert h[v] >= h[u] + 1
            assert a[u] >= a[v] + 1


@given(st.integers(10, 120), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_random_dag_reference_eval_finite(n, seed):
    g = wl.random_dag(n, seed=seed)
    g.validate()
    vals = reference_evaluate(g)
    assert np.isfinite(vals).all()


def test_criticality_metrics_exist():
    g = wl.reduction_tree(16)
    for m in ("height", "neg_slack", "fanout_height"):
        c = criticality(g, m)
        assert c.shape == (g.num_nodes,)
    with pytest.raises(ValueError):
        criticality(g, "bogus")


def test_workload_generators_shapes():
    for g in [wl.chain(8), wl.reduction_tree(9), wl.layered_dag(4, 6),
              wl.sparse_lu_graph(8, 0.4, seed=1), wl.banded_lu_graph(12, 3),
              wl.arrow_lu_graph(2, 4, 3), wl.elimination_tree_graph(2, 3, 4)]:
        g.validate()
        assert g.num_nodes > 0
        assert np.isfinite(reference_evaluate(g)).all()
