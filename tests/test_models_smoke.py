"""Per-architecture smoke tests (required deliverable f): reduced config of
the same family, one forward AND one train step on CPU, asserting output
shapes and absence of NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.optim import AdamW
from repro.train.steps import init_train_state, make_train_step


def _batch(cfg, rng, b=2, t=16):
    if cfg.encdec is not None:
        return {
            "frames": jnp.asarray(rng.standard_normal((b, 24, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jnp.asarray(rng.standard_normal((b, t, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    b, t = 2, 16
    batch = _batch(cfg, rng, b, t)
    if cfg.encdec is not None:
        h, _ = lm.forward_encdec(params, cfg, batch["frames"], batch["tokens"])
    elif cfg.family == "vlm":
        h, _ = lm.forward(params, cfg, embeds=batch["embeds"])
    else:
        h, _ = lm.forward(params, cfg, tokens=batch["tokens"])
    assert h.shape == (b, t, cfg.d_model)
    logits = lm.logits_fn(params, cfg, h)
    assert logits.shape == (b, t, cfg.padded_vocab)
    assert bool(jnp.isfinite(h).all())
    # pad columns masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt = AdamW(lr=1e-3)
    state = init_train_state(jax.random.key(1), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    rng = np.random.default_rng(1)
    state, m = step(state, _batch(cfg, rng))
    assert np.isfinite(float(m["loss"]))
    assert int(state["step"]) == 1
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.isfinite(leaf).all())
