"""Pallas kernels (interpret=True on CPU) vs pure-jnp oracles: shape/dtype
sweeps as required per kernel."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import bitvec
from repro.kernels import ops, ref


@pytest.mark.parametrize("p,w", [(1, 1), (7, 3), (64, 1), (256, 13), (300, 40), (8, 128)])
def test_lod_matches_ref(p, w):
    rng = np.random.default_rng(p * 1000 + w)
    bits = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    bits[rng.random((p, w)) < 0.5] = 0
    bits[0] = 0  # empty row -> -1
    got = ops.lod(jnp.asarray(bits))
    want = ref.lod_ref(jnp.asarray(bits))
    np.testing.assert_array_equal(got, want)
    assert int(got[0]) == -1


@pytest.mark.parametrize("p,w", [(4, 2), (256, 13), (128, 40)])
def test_schedule_step_matches_ref(p, w):
    rng = np.random.default_rng(p + w)
    bits = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    bits[rng.random((p, w)) < 0.5] = 0
    s_got, nb_got = ops.schedule_step(jnp.asarray(bits))
    s_want, nb_want = ref.schedule_step_ref(jnp.asarray(bits))
    np.testing.assert_array_equal(s_got, s_want)
    np.testing.assert_array_equal(nb_got, nb_want)


@pytest.mark.parametrize("p,w", [(4, 2), (256, 13), (37, 5)])
def test_schedule_step_gated(p, w):
    rng = np.random.default_rng(p * 7 + w)
    bits = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    bits[rng.random((p, w)) < 0.5] = 0
    gate = rng.random(p) < 0.5
    s_got, nb_got = ops.schedule_step(jnp.asarray(bits), jnp.asarray(gate))
    s_want, nb_want = ref.schedule_step_ref(jnp.asarray(bits), jnp.asarray(gate))
    np.testing.assert_array_equal(s_got, s_want)
    np.testing.assert_array_equal(nb_got, nb_want)
    # ungated rows still pick but must keep their bits intact
    np.testing.assert_array_equal(np.asarray(nb_got)[~gate], bits[~gate])
    s_all, _ = ops.schedule_step(jnp.asarray(bits))
    np.testing.assert_array_equal(s_got, s_all)


@pytest.mark.parametrize("p,w", [(4, 2), (256, 13), (37, 5)])
def test_rotating_schedule_step_matches_ref(p, w):
    rng = np.random.default_rng(p * 13 + w)
    bits = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    bits[rng.random((p, w)) < 0.5] = 0
    ptr = rng.integers(0, w * 32, size=p, dtype=np.int32)
    gate = rng.random(p) < 0.7
    got = ops.rotating_schedule_step(jnp.asarray(bits), jnp.asarray(ptr),
                                     jnp.asarray(gate))
    want = ref.rotating_schedule_step_ref(jnp.asarray(bits), jnp.asarray(ptr),
                                          jnp.asarray(gate))
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_rotating_schedule_step_semantics():
    # one row, flags at slots 3 and 40 (W=2): ptr selects the rotating window
    bits = bitvec.set_bit(jnp.zeros((1, 2), jnp.uint32), jnp.asarray([0]),
                          jnp.asarray([3]), jnp.asarray([True]))
    bits = bitvec.set_bit(bits, jnp.asarray([0]), jnp.asarray([40]),
                          jnp.asarray([True]))
    for ptr, want in [(0, 3), (3, 3), (4, 40), (40, 40), (41, 3)]:
        slot, nb = ops.rotating_schedule_step(bits, jnp.asarray([ptr]))
        assert int(slot[0]) == want, (ptr, int(slot[0]))
        assert not bool(bitvec.test_bit(nb, jnp.asarray([0]),
                                        jnp.asarray([want]))[0])
    # and the rotating ref matches the jnp scheduler policy's select
    from repro.core import schedulers
    rng = np.random.default_rng(5)
    rbits = jnp.asarray(rng.integers(0, 2**32, size=(1, 24, 3), dtype=np.uint32))
    rptr = jnp.asarray(rng.integers(0, 96, size=(1, 24), dtype=np.int32))
    pol = schedulers.get("lru_flat")
    cand, have = pol.select(dict(rdy=rbits, ptr=rptr), jnp.ones((1, 24), bool))
    slot, _ = ops.rotating_schedule_step(rbits.reshape(24, 3), rptr.reshape(24))
    np.testing.assert_array_equal(np.asarray(cand).reshape(-1), np.asarray(slot))


def test_schedule_step_drains_all_bits():
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.integers(0, 2**32, size=(8, 4), dtype=np.uint32))
    total = int(bitvec.count_set(bits).sum())
    for _ in range(total):
        slot, bits = ops.schedule_step(bits)
    assert int(bitvec.count_set(bits).sum()) == 0
    slot, _ = ops.schedule_step(bits)
    assert (np.asarray(slot) == -1).all()


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_lod_property(p, w, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    got = np.asarray(ops.lod(jnp.asarray(bits)))
    for i in range(p):
        row = bits[i]
        if row.any():
            word = int(np.argmax(row != 0))
            bit = 31 - int(np.floor(np.log2(row[word])))
            assert got[i] == word * 32 + bit
        else:
            assert got[i] == -1


@pytest.mark.parametrize(
    "b,hq,hkv,tq,tkv,d,causal,dtype",
    [
        (2, 4, 2, 128, 128, 64, True, np.float32),
        (1, 2, 1, 64, 256, 128, True, np.float32),
        (1, 4, 4, 128, 128, 80, False, np.float32),
        (2, 2, 2, 96, 160, 64, True, np.float32),
        (1, 2, 2, 128, 128, 64, True, np.dtype("bfloat16")),
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, tq, tkv, d, causal, dtype):
    rng = np.random.default_rng(3)
    q = rng.standard_normal((b, hq, tq, d)).astype(dtype)
    k = rng.standard_normal((b, hkv, tkv, d)).astype(dtype)
    v = rng.standard_normal((b, hkv, tkv, d)).astype(dtype)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                   causal=causal)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_bitvec_set_clear_roundtrip():
    bits = jnp.zeros((4, 2), jnp.uint32)
    pe = jnp.arange(4)
    slot = jnp.asarray([0, 31, 32, 63])
    on = jnp.asarray([True, True, True, False])
    bits = bitvec.set_bit(bits, pe, slot, on)
    got = bitvec.test_bit(bits, pe, slot)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(on))
    assert int(bitvec.count_set(bits).sum()) == 3
