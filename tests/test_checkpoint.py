"""Checkpointing: atomic roundtrip, keep_n GC, resume-exactness."""
import os
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs import get_config
from repro.data.pipeline import SyntheticCopyTask
from repro.optim import AdamW
from repro.train.steps import init_train_state, make_train_step


def test_roundtrip_and_gc(tmp_path):
    cm = ckpt.CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3))}}
    for s in (1, 2, 3):
        cm.save(s, tree)
    assert cm.latest_step() == 3
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2  # GC kept last 2
    back = cm.restore_latest(tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async_save(tmp_path):
    cm = ckpt.CheckpointManager(str(tmp_path), keep_n=3, async_save=True)
    tree = {"w": jnp.zeros(10)}
    cm.save(5, tree)
    cm.wait()
    assert cm.latest_step() == 5


def test_no_partial_checkpoint_on_restore_error(tmp_path):
    cm = ckpt.CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, {"a": jnp.zeros(3)})
    try:
        cm.restore_latest({"a": jnp.zeros(3), "extra": jnp.zeros(1)})
        raised = False
    except ValueError:
        raised = True
    assert raised


def test_training_resume_exactness(tmp_path):
    """Crash/restart: restoring the checkpoint and replaying the
    deterministic data stream reproduces the uninterrupted run exactly."""
    cfg = get_config("minicpm-2b", smoke=True)
    opt = AdamW(lr=1e-3)
    ds = SyntheticCopyTask(cfg.vocab_size, batch=8, seq=16, seed=1)
    step = jax.jit(make_train_step(cfg, opt))

    state = init_train_state(jax.random.key(0), cfg, opt)
    for i in range(4):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()})
    cm = ckpt.CheckpointManager(str(tmp_path), async_save=False)
    cm.save(4, state)
    for i in range(4, 8):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()})

    # simulated failure: restore at step 4 and replay
    resumed = cm.restore_latest(jax.tree.map(lambda x: x, state))
    resumed = jax.tree.map(jnp.asarray, resumed)
    for i in range(4, 8):
        resumed, _ = step(resumed, {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()})
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
