"""Gradient compression, elastic remesh, HLO cost walker."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed import compression as comp
from repro.distributed.elastic import remesh, rescale_batch
from repro.distributed.hlo_cost import analyze
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamW
from repro.train.steps import init_train_state


def test_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    e = comp.init_error(g)
    g_hat, e2 = comp.compress_roundtrip(g, e)
    err = float(jnp.abs(g_hat["w"] - g["w"]).max())
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert err <= scale * 0.5 + 1e-7
    # error feedback: residual equals quantization error exactly
    np.testing.assert_allclose(np.asarray(e2["w"]),
                               np.asarray(g["w"] - g_hat["w"]), rtol=1e-6)


def test_compression_error_feedback_converges():
    """Sum over steps of dequantized grads tracks the true sum (EF property)."""
    rng = np.random.default_rng(1)
    true = jnp.asarray(rng.standard_normal(128) * 1e-3, jnp.float32)
    e = {"w": jnp.zeros(128)}
    acc = jnp.zeros(128)
    for _ in range(50):
        g_hat, e = comp.compress_roundtrip({"w": true}, e)
        acc = acc + g_hat["w"]
    np.testing.assert_allclose(np.asarray(acc), np.asarray(true * 50),
                               rtol=0.02, atol=1e-4)


def test_elastic_remesh_roundtrip():
    cfg = get_config("qwen2-0.5b", smoke=True)
    opt = AdamW(lr=1e-3)
    state = init_train_state(jax.random.key(0), cfg, opt)
    mesh = make_local_mesh(1, 1)
    state2 = remesh(cfg, state, mesh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert rescale_batch(256, 16, 8) == 32
    try:
        rescale_batch(256, 16, 7)
        raised = False
    except ValueError:
        raised = True
    assert raised


def test_hlo_cost_walker_counts_scan_trips():
    def body(c, x):
        return c @ x, None

    def f(c, xs):
        out, _ = jax.lax.scan(body, c, xs)
        return out

    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    comp5 = jax.jit(f).lower(c, xs).compile()
    r = analyze(comp5.as_text())
    want = 5 * 2 * 64**3
    assert abs(r["flops"] - want) / want < 0.05
    assert not r["unknown_trips"]
