"""Sharding rules: spec construction for every arch, divisibility guard, and
an SPMD compile in a subprocess with 8 fake devices (the in-process backend
is pinned to 1 CPU device for all other tests)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.optim import AdamW
from repro.train import steps as tsteps


@pytest.fixture(scope="module")
def mesh1():
    return make_local_mesh(1, 1)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_tree(arch, mesh1):
    cfg = get_config(arch, smoke=True)
    params_abs = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.key(0))
    specs = shd.param_specs(cfg, params_abs, mesh1)
    n_params = len(jax.tree.leaves(params_abs))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_params == n_specs


def test_divisibility_guard():
    mesh = make_local_mesh(1, 1)
    # fake a 4-way model axis via mesh.shape lookups: use fix_divisibility directly
    class FakeMesh:
        shape = {"data": 2, "model": 4}
        axis_names = ("data", "model")
    s = shd.fix_divisibility(P(None, "model"), (10, 6), FakeMesh)
    assert s == P(None, None)        # 6 % 4 != 0 -> replicated
    s = shd.fix_divisibility(P("data", "model"), (10, 8), FakeMesh)
    assert s == P("data", "model")
    s = shd.fix_divisibility(P(("data", "model"), None), (16, 3), FakeMesh)
    assert s == P(("data", "model"), None)


def test_state_specs_mirror_params(mesh1):
    cfg = get_config("qwen2-0.5b", smoke=True)
    opt = AdamW(lr=1e-3)
    st = jax.eval_shape(lambda k: tsteps.init_train_state(k, cfg, opt), jax.random.key(0))
    ss = shd.state_specs(cfg, st, mesh1)
    assert "master" in ss["opt"]
    assert ss["step"] == P()


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.optim import AdamW
from repro.train import steps as tsteps
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_config("qwen2-0.5b", smoke=True), fsdp=True)
opt = AdamW(lr=1e-3)
state_abs = jax.eval_shape(lambda k: tsteps.init_train_state(k, cfg, opt), jax.random.key(0))
sspecs = shd.state_specs(cfg, state_abs, mesh)
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
bspecs = shd.batch_specs(cfg, batch, mesh)
fn = tsteps.make_train_step(cfg, opt)
jfn = jax.jit(fn, in_shardings=(shd.to_shardings(mesh, sspecs), shd.to_shardings(mesh, bspecs)),
              out_shardings=(shd.to_shardings(mesh, sspecs), None), donate_argnums=0)
with mesh:
    jfn.lower(state_abs, batch).compile()
print("SPMD_OK")
"""


@pytest.mark.slow
def test_spmd_train_compiles_on_fake_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], cwd=os.getcwd(),
                         capture_output=True, text=True, env=env, timeout=420)
    assert "SPMD_OK" in out.stdout, out.stderr[-2000:]
