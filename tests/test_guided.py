"""Surrogate-guided annealing (repro.surrogate.delta + place.anneal):
incremental move features are bit-exact against batch recompute, the
open-gate guided kernel reproduces the unguided annealer bit-for-bit, guided
searches are deterministic with exact cost-evaluation counters, the quotient
guide's coarse-level features equal the fine features of the projected
placement, and the guide knobs thread through PlacementSpec/resolve."""
import dataclasses

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro import place, surrogate
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.surrogate import delta as sd

G = wl.arrow_lu_graph(2, 6, 4, seed=3)
NX, NY = 4, 5                      # non-square: catches x/y coordinate swaps
ACFG = place.AnnealConfig(replicas=6, rounds=8, steps=128, seed=0)
CFG = OverlayConfig(max_cycles=200_000)


@pytest.fixture(scope="module")
def model():
    m, _, _ = surrogate.fit_from_sim(G, NX, NY, cfg=CFG, n_train=12, seed=0)
    return m


# ---------------------------------------------------------------------------
# Incremental features (surrogate.delta).
# ---------------------------------------------------------------------------

def test_delta_features_match_batch_recompute_bit_exactly(model):
    guide = sd.build_guide(model)
    ga = sd.guide_arrays(guide)
    ex = guide.extractor
    rng = np.random.default_rng(7)
    pe = rng.integers(0, NX * NY, size=G.num_nodes).astype(np.int32)
    with enable_x64():
        st = sd.state_init(ga, pe, nx=NX, ny=NY)
        np.testing.assert_array_equal(
            np.asarray(st.feats), ex.features_batch(pe)[0].astype(np.int64))
        for k in range(150):
            i = int(rng.integers(0, G.num_nodes))
            q = int(rng.integers(0, NX * NY))
            st, dscore = sd.apply_move(ga, st, pe, i, np.int32(q),
                                       nx=NX, ny=NY)
            pe = pe.copy()
            pe[i] = q
            if k % 50 == 49:   # carried state never drifts from recompute
                np.testing.assert_array_equal(
                    np.asarray(st.feats),
                    ex.features_batch(pe)[0].astype(np.int64))


def test_delta_score_is_quantized_prediction_delta(model):
    guide = sd.build_guide(model)
    ga = sd.guide_arrays(guide)
    rng = np.random.default_rng(3)
    pe = rng.integers(0, NX * NY, size=G.num_nodes).astype(np.int32)
    pe2 = pe.copy()
    pe2[11] = (pe[11] + 3) % (NX * NY)
    with enable_x64():
        st = sd.state_init(ga, pe, nx=NX, ny=NY)
        _, dscore = sd.apply_move(ga, st, pe, 11, np.int32(pe2[11]),
                                  nx=NX, ny=NY)
    f1 = model.extractor.features_batch(pe)[0].astype(np.int64)
    f2 = model.extractor.features_batch(pe2)[0].astype(np.int64)
    assert int(dscore) == int(np.sum(guide.gamma_q * (f2 - f1)))
    # ... and it tracks the float model's predicted delta within the exact
    # quantization bound: each coefficient is off by <= 0.5/GUIDE_SCALE.
    pred = model.predict_batch(np.stack([pe, pe2]))
    bound = 0.5 * np.abs(f2 - f1).sum() / sd.GUIDE_SCALE + 1e-9
    assert int(dscore) / sd.GUIDE_SCALE == pytest.approx(
        pred[1] - pred[0], abs=bound)


def test_quotient_guide_features_equal_projected_fine(model):
    guide = sd.build_guide(model)
    clusters = place.cluster_nodes(G, 8)
    cguide = guide.coarsen(clusters)
    c = int(clusters.max()) + 1
    rng = np.random.default_rng(5)
    cpe = rng.integers(0, NX * NY, size=(4, c)).astype(np.int32)
    np.testing.assert_array_equal(
        cguide.extractor.features_batch(cpe),
        guide.extractor.features_batch(cpe[:, clusters]))
    np.testing.assert_array_equal(cguide.gamma_q, guide.gamma_q)


def test_quantize_margin():
    assert sd.quantize_margin(0.0) == 0
    assert sd.quantize_margin(1.0) == sd.GUIDE_SCALE
    assert sd.quantize_margin(float("inf")) == np.iinfo(np.int64).max
    assert sd.quantize_margin(float("-inf")) == np.iinfo(np.int64).min


# ---------------------------------------------------------------------------
# Guided annealer.
# ---------------------------------------------------------------------------

def test_open_gate_reproduces_unguided_bit_exactly(model):
    plain = place.anneal_placement(G, NX, NY, ACFG)
    guided = place.anneal_placement(G, NX, NY, ACFG, guide=model,
                                    guide_margin=float("inf"))
    np.testing.assert_array_equal(plain.node_pe, guided.node_pe)
    assert plain.cost == guided.cost
    np.testing.assert_array_equal(plain.replica_costs, guided.replica_costs)
    # With the gate wide open every proposal reaches the cost rule.
    assert guided.cost_evals == guided.proposals
    assert guided.proposals == ACFG.replicas * ACFG.rounds * ACFG.steps


def test_guided_deterministic_with_exact_counters(model):
    a = place.anneal_placement(G, NX, NY, ACFG, guide=model, guide_margin=0.0)
    b = place.anneal_placement(G, NX, NY, ACFG, guide=model, guide_margin=0.0)
    np.testing.assert_array_equal(a.node_pe, b.node_pe)
    assert (a.cost, a.cost_evals) == (b.cost, b.cost_evals)
    assert isinstance(a, place.GuidedPlacementResult)
    assert 0 < a.cost_evals < a.proposals   # the gate actually filters
    assert a.eval_ratio == a.cost_evals / a.proposals
    assert a.cost <= a.init_cost            # best-so-far includes the init


def test_guide_every_skips_gate_on_off_steps(model):
    every = place.anneal_placement(G, NX, NY, ACFG, guide=model,
                                   guide_margin=0.0, guide_every=1)
    sparse = place.anneal_placement(G, NX, NY, ACFG, guide=model,
                                    guide_margin=0.0, guide_every=4)
    # Ungated proposals always reach the cost rule, so gating every 4th
    # proposal evaluates strictly more than gating every proposal.
    assert sparse.cost_evals > every.cost_evals
    assert sparse.cost_evals >= (3 * sparse.proposals) // 4


def test_guide_graph_grid_mismatch_raises(model):
    other = wl.arrow_lu_graph(2, 5, 3, seed=1)
    with pytest.raises(ValueError, match="guide was built"):
        place.anneal_placement(other, NX, NY, ACFG, guide=model)
    with pytest.raises(ValueError, match="guide was built"):
        place.anneal_placement(G, NY, NX, ACFG, guide=model)
    with pytest.raises(ValueError, match="guide_every"):
        place.anneal_placement(G, NX, NY, ACFG, guide=model, guide_every=0)


def test_multilevel_guided_identity_open_gate_matches_plain(model):
    plain = place.anneal_placement(G, NX, NY, ACFG)
    ml = place.multilevel_anneal(
        G, NX, NY, ACFG, clusters=np.arange(G.num_nodes), refine=None,
        guide=model, guide_margin=float("inf"))
    np.testing.assert_array_equal(ml.node_pe, plain.node_pe)
    assert ml.coarse.cost == plain.cost


def test_multilevel_guided_runs_and_is_deterministic(model):
    a = place.multilevel_anneal(G, NX, NY, ACFG, ratio=8, guide=model,
                                guide_margin=0.0)
    b = place.multilevel_anneal(G, NX, NY, ACFG, ratio=8, guide=model,
                                guide_margin=0.0)
    np.testing.assert_array_equal(a.node_pe, b.node_pe)
    assert isinstance(a.coarse, place.GuidedPlacementResult)
    assert isinstance(a.refined, place.GuidedPlacementResult)
    assert a.coarse.cost_evals < a.coarse.proposals


def test_int64_thresholds_survive_ambient_x32():
    # Two acceptance thresholds both far above any possible move delta on
    # this graph must behave identically — they would wrap to different
    # int32 values if the threshold array were converted outside scoped x64.
    big = dataclasses.replace(ACFG, replicas=2, rounds=2, steps=64,
                              t_max=3e9)
    huge = dataclasses.replace(big, t_max=1e12)
    a = place.anneal_placement(G, NX, NY, big)
    b = place.anneal_placement(G, NX, NY, huge)
    np.testing.assert_array_equal(a.node_pe, b.node_pe)
    assert a.cost == b.cost


# ---------------------------------------------------------------------------
# Spec threading.
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="guide"):
        place.PlacementSpec(strategy="anneal", guide="bogus")
    with pytest.raises(ValueError, match="guide_every"):
        place.PlacementSpec(guide_every=0)
    with pytest.raises(ValueError, match="guide_train"):
        place.PlacementSpec(guide_train=1)
    # A guide on a non-search strategy would be silently ignored — reject.
    with pytest.raises(ValueError, match="search strategy"):
        place.PlacementSpec(guide="surrogate")
    with pytest.raises(ValueError, match="search strategy"):
        place.PlacementSpec(strategy="random", guide="surrogate")
    place.PlacementSpec(strategy="multilevel", guide="surrogate")  # fine


def test_resolve_guided_spec_deterministic_and_uses_prefit(model):
    spec = place.PlacementSpec(strategy="anneal", guide="surrogate",
                               anneal=ACFG, guide_margin=0.0, guide_train=8)
    via_prefit = place.resolve(G, NX, NY, spec, guide_model=model)
    direct = place.anneal_placement(G, NX, NY, ACFG, guide=model,
                                    guide_margin=0.0)
    np.testing.assert_array_equal(via_prefit, direct.node_pe)
    # Auto-fit path: deterministic end to end (fit seeds from spec.seed).
    a = place.resolve(G, NX, NY, spec)
    b = place.resolve(G, NX, NY, spec)
    np.testing.assert_array_equal(a, b)
