"""repro.telemetry contract tests.

Three claims pin the tentpole down:

  1. *Telemetry is invisible when off*: PR-6-era results (cycles, stats,
     node values) are bit-identical with the split deflection counters in
     place, for every policy x engine x chunk depth.
  2. *Telemetry is an observer when on*: simulated cycles/stats don't move,
     traces are bit-identical across engines, chunk depths and entry points
     (batched row b == solo run of config b; sharded == single-device), and
     trace sums equal the scalar stat counters exactly.
  3. *Exports are well-formed*: the Perfetto/Chrome-trace JSON round-trips
     through ``json`` and carries exactly the advertised counter-track
     count; the report's integers are consistent with the stats.
"""
import json

import numpy as np
import pytest

from repro.core import schedulers
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig, simulate, simulate_batch
from repro.core.partition import build_graph_memory
from repro.telemetry import TelemetrySpec
from repro.telemetry.perfetto import track_count

ALL_POLICIES = sorted(schedulers.REGISTRY)
ENGINES = ("jnp", "select", "megakernel")
SPEC = TelemetrySpec(buckets=16, bucket_cycles=8)


def _gm(sched="ooo", nx=2, ny=2):
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    policy = schedulers.get(sched)
    return build_graph_memory(g, nx, ny,
                              criticality_order=policy.wants_criticality_order)


def _stats(r):
    return (r.done, r.cycles, r.deflections, r.busy_cycles, r.delivered)


@pytest.fixture(scope="module")
def reference_runs():
    """Per policy: (telemetry-off, telemetry-on) check_every=1 references."""
    out = {}
    for sched in ALL_POLICIES:
        gm = _gm(sched)
        off = simulate(gm, OverlayConfig(scheduler=sched, check_every=1))
        on = simulate(gm, OverlayConfig(scheduler=sched, check_every=1,
                                        telemetry=SPEC))
        assert off.done and on.done
        out[sched] = (off, on)
    return out


# ---------------------------------------------------------------------------
# 1. telemetry=None leaves the model bit-exact (incl. the deflection split)
# ---------------------------------------------------------------------------

# Engine x chunk-depth sampling: the jnp reference path runs the full policy
# matrix; the Pallas engines run representative policies here because their
# full policy x chunk-depth off-matrices are already pinned bit-for-bit by
# tests/test_chunked.py and tests/test_megakernel.py against the same
# check_every=1 reference these fixtures rebuild.
OFF_MATRIX = [
    ("jnp", 1, ALL_POLICIES), ("jnp", 8, ALL_POLICIES),
    ("jnp", 32, ALL_POLICIES),
    ("select", 8, ("ooo", "scan")),
    ("megakernel", 8, ("ooo", "inorder")),
    ("megakernel", 32, ("lru_flat",)),
]


@pytest.mark.parametrize("engine,check_every,policies", OFF_MATRIX)
def test_off_bit_exact(engine, check_every, policies, reference_runs):
    for sched in policies:
        gm = _gm(sched)
        r = simulate(gm, OverlayConfig(scheduler=sched, engine=engine,
                                       check_every=check_every))
        ref = reference_runs[sched][0]
        assert _stats(r) == _stats(ref), (sched, check_every, engine)
        np.testing.assert_array_equal(r.values, ref.values)
        assert r.telemetry is None


def test_deflection_split_sums(reference_runs):
    for sched in ALL_POLICIES:
        r = reference_runs[sched][0]
        assert r.noc_deflections + r.eject_deflections == r.deflections
        assert r.noc_deflections >= 0 and r.eject_deflections >= 0


# ---------------------------------------------------------------------------
# 2. telemetry on: cycles unchanged, traces engine/chunk/entry-point exact
# ---------------------------------------------------------------------------

def _assert_same_traces(a, b, ctx):
    assert set(a.traces) == set(b.traces), ctx
    for k in a.traces:
        np.testing.assert_array_equal(a.traces[k], b.traces[k], err_msg=str((ctx, k)))


ON_MATRIX = [
    ("jnp", 1, ALL_POLICIES), ("jnp", 8, ALL_POLICIES),
    ("jnp", 32, ALL_POLICIES),
    ("select", 8, ("ooo", "lru_flat")),
    ("select", 32, ("scan",)),
    ("megakernel", 8, ("ooo", "inorder")),
    ("megakernel", 32, ("lru_flat",)),
]


@pytest.mark.parametrize("engine,check_every,policies", ON_MATRIX)
def test_on_bit_exact(engine, check_every, policies, reference_runs):
    for sched in policies:
        gm = _gm(sched)
        r = simulate(gm, OverlayConfig(scheduler=sched, engine=engine,
                                       check_every=check_every, telemetry=SPEC))
        off, on = reference_runs[sched]
        # tracing never moves the model...
        assert _stats(r) == _stats(off), (sched, check_every, engine)
        np.testing.assert_array_equal(r.values, off.values)
        # ...and the traces themselves are engine/chunk-depth invariant
        # (stall_no_ready is the overshoot-repair witness).
        _assert_same_traces(r.telemetry, on.telemetry, (sched, check_every, engine))


def test_trace_sums_equal_counters(reference_runs):
    for sched in ALL_POLICIES:
        r = reference_runs[sched][1]
        t = r.telemetry.traces
        assert int(t["pe_busy"].sum()) == r.busy_cycles
        assert int(t["defl_noc"].sum()) == r.noc_deflections
        assert int(t["defl_eject"].sum()) == r.eject_deflections
        assert int(t["eject_grant"].sum()) == r.delivered
        # every PE-cycle is attributed at most once per stall cause, and
        # no-ready stalls can never exceed total idle PE-cycles
        total_pe_cycles = r.cycles * r.telemetry.nx * r.telemetry.ny
        occupied = int(t["pe_occ"].sum())
        assert int(t["stall_no_ready"].sum()) <= total_pe_cycles - occupied
        assert (t["stall_no_ready"] >= 0).all()  # overshoot repair exact
        # wavefront is monotone and ends at the total fire count
        wf = r.telemetry.wavefront()
        assert (np.diff(wf) >= 0).all() and wf[-1] == r.busy_cycles


def test_batched_rows_match_solo():
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    gm = build_graph_memory(g, 4, 4, criticality_order=True)
    policies = ("ooo", "lru_flat", "scan")
    rs = simulate_batch(gm, [OverlayConfig(scheduler=p, telemetry=SPEC)
                             for p in policies])
    for b, p in enumerate(policies):
        solo = simulate(gm, OverlayConfig(scheduler=p, telemetry=SPEC))
        assert _stats(rs[b]) == _stats(solo), p
        _assert_same_traces(rs[b].telemetry, solo.telemetry, p)


def test_batched_requires_uniform_telemetry():
    gm = _gm()
    with pytest.raises(ValueError, match="uniform telemetry"):
        simulate_batch(gm, [OverlayConfig(telemetry=SPEC),
                            OverlayConfig(telemetry=None)])


def test_sharded_matches_solo():
    import jax
    from jax.sharding import Mesh
    from repro.core import distributed

    gm = _gm(nx=4, ny=4)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    solo = simulate(gm, OverlayConfig(telemetry=SPEC))
    r = distributed.simulate_sharded(gm, mesh, OverlayConfig(telemetry=SPEC))
    assert _stats(r) == _stats(solo)
    _assert_same_traces(r.telemetry, solo.telemetry, "sharded")
    rs = distributed.simulate_batch_sharded(
        gm, mesh, [OverlayConfig(scheduler=s, telemetry=SPEC)
                   for s in ("ooo", "inorder")])
    # rows share gm's packed memory image, so each solo reference must too
    for b, s in enumerate(("ooo", "inorder")):
        ref = simulate(gm, OverlayConfig(scheduler=s, telemetry=SPEC))
        assert _stats(rs[b]) == _stats(ref), s
        _assert_same_traces(rs[b].telemetry, ref.telemetry, ("batch-sharded", s))


def test_spec_validation():
    with pytest.raises(ValueError, match="buckets"):
        TelemetrySpec(buckets=0)
    with pytest.raises(ValueError, match="records nothing"):
        TelemetrySpec(pe=False, links=False, eject=False, sched=False,
                      stalls=False)
    with pytest.raises(TypeError, match="TelemetrySpec"):
        OverlayConfig(telemetry="yes please")
    # partial specs only allocate what they trace
    slim = TelemetrySpec(pe=True, links=False, eject=False, sched=False,
                        stalls=False)
    r = simulate(_gm(), OverlayConfig(telemetry=slim))
    assert set(r.telemetry.traces) == {"pe_busy", "pe_occ"}
    assert int(r.telemetry.traces["pe_busy"].sum()) == r.busy_cycles


def test_bucket_clamp_keeps_sums():
    # horizon far shorter than the run: everything past it lands in the
    # last bucket instead of being dropped
    tiny = TelemetrySpec(buckets=2, bucket_cycles=4)
    r = simulate(_gm(), OverlayConfig(telemetry=tiny))
    t = r.telemetry.traces
    assert r.cycles > tiny.horizon
    assert int(t["pe_busy"].sum()) == r.busy_cycles
    assert int(t["pe_busy"][-1].sum()) > 0


# ---------------------------------------------------------------------------
# 3. exports
# ---------------------------------------------------------------------------

def test_perfetto_export_valid_json(tmp_path, reference_runs):
    r = reference_runs["ooo"][1]
    path = tmp_path / "trace.json"
    r.telemetry.export_perfetto(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["otherData"]["generator"] == "repro.telemetry"
    counters = [e for e in loaded["traceEvents"] if e["ph"] == "C"]
    assert counters and all("ts" in e and "args" in e for e in counters)
    tracks = {(e["pid"], e["name"]) for e in counters}
    assert len(tracks) == track_count(SPEC, 2, 2)
    # 2x2 grid, all groups on: 4 PE + 1 wavefront + 12 link + 4 eject + 1
    assert len(tracks) == 22


def test_report_consistent(reference_runs):
    r = reference_runs["ooo"][1]
    rep = r.telemetry.report(top_k=3)
    assert rep["cycles"] == r.cycles
    assert rep["pe"]["busy_total"] == r.busy_cycles
    assert rep["links"]["defl_noc"] == r.noc_deflections
    assert rep["links"]["defl_eject"] == r.eject_deflections
    assert rep["stalls"]["eject_deflected"] == r.eject_deflections
    assert len(rep["links"]["top"]) == 3
    assert rep["links"]["top"][0]["busy"] == rep["links"]["busy_max"]
    assert 0.0 <= rep["links"]["util_p50"] <= rep["links"]["util_p95"] <= 1.0
    json.dumps(rep)  # report is JSON-serializable as-is (BENCH section)
    heat = r.telemetry.ascii_heatmap("pe_busy")
    assert heat.count("\n") == r.telemetry.nx
