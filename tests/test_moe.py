"""MoE dispatch: combine-weight correctness, capacity drops, brute-force
equivalence with per-token expert evaluation."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import moe
from repro.models.common import ModelConfig, MoECfg


def _cfg(e=4, k=2, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, dtype="float32",
        moe=MoECfg(num_experts=e, top_k=k, d_ff_expert=32, capacity_factor=cf))


def test_moe_matches_bruteforce_no_drops():
    cfg = _cfg()
    params = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 10, 16), jnp.float32)
    out, m = moe.apply(params, cfg, x)
    assert float(m["moe_dropped"]) == 0.0

    # brute force: evaluate every expert densely, combine with router weights
    logits = x @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, cfg.moe.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    dense = []
    for e in range(cfg.moe.num_experts):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        dense.append(h @ params["w_down"][e])
    dense = jnp.stack(dense, axis=2)             # [b,t,E,d]
    mask = jax.nn.one_hot(topi, cfg.moe.num_experts) * topw[..., None]
    want = jnp.einsum("btke,bted->btd", mask, dense)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_moe_capacity_drops_counted():
    cfg = _cfg(cf=0.01)  # capacity 1 slot per expert
    params = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (1, 32, 16), jnp.float32)
    out, m = moe.apply(params, cfg, x)
    assert float(m["moe_dropped"]) > 0
    assert bool(jnp.isfinite(out).all())


def test_moe_shared_experts_added():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_shared=1))
    params = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 6, 16), jnp.float32)
    out, _ = moe.apply(params, cfg, x)
    s = params["shared"]
    hs = jax.nn.silu(x @ s["w_gate"]["w"]) * (x @ s["w_up"]["w"])
    shared_only = hs @ s["w_down"]["w"]
    # removing the shared contribution recovers the routed-only output
    cfg2 = _cfg()
    params2 = dict(params)
    params2.pop("shared")
    routed, _ = moe.apply(params2, cfg2, x)
    np.testing.assert_allclose(np.asarray(out - shared_only), np.asarray(routed),
                               rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_uniform_router_is_one():
    cfg = _cfg()
    params = moe.init(jax.random.key(0), cfg)
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(jax.random.key(1), (2, 64, 16), jnp.float32)
    _, m = moe.apply(params, cfg, x)
    # uniform probs: E * sum_e (1/E * f_e) = k (top-k fractions sum to k)
    assert float(m["moe_aux"]) == jax.numpy.asarray(cfg.moe.top_k, jnp.float32)


def test_criticality_dispatch_keeps_more_router_mass():
    """Paper-technique integration: under capacity pressure the
    criticality-ordered cut retains more routed probability mass than
    arrival-order FCFS (and is identical when nothing drops)."""
    import math
    cfg_c = _cfg(cf=0.15)
    cfg_a = dataclasses.replace(
        cfg_c, moe=dataclasses.replace(cfg_c.moe, dispatch_order="arrival"))
    params = moe.init(jax.random.key(0), cfg_c)
    x = jax.random.normal(jax.random.key(1), (2, 12, 16), jnp.float32)

    def kept_mass(cfg):
        t, e, k = x.shape[1], cfg.moe.num_experts, cfg.moe.top_k
        cap = min(max(1, math.ceil(k * t * cfg.moe.capacity_factor / e)), t * k)
        logits = x @ params["router"]["w"]
        probs = jax.nn.softmax(logits, -1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / topw.sum(-1, keepdims=True)
        fe = topi.reshape(2, t * k)
        fw = topw.reshape(2, t * k)
        if cfg.moe.dispatch_order == "criticality":
            key = fe.astype(jnp.float32) * 2.0 + (1.0 - fw)
            o = jnp.argsort(key, axis=1)
            fes = jnp.take_along_axis(fe, o, 1)
            oh = jax.nn.one_hot(fes, e, dtype=jnp.int32)
            ps = jnp.take_along_axis(jnp.cumsum(oh, 1) - 1, fes[..., None], -1)[..., 0]
            mypos = jnp.zeros_like(ps).at[jnp.arange(2)[:, None], o].set(ps)
        else:
            oh = jax.nn.one_hot(fe, e, dtype=jnp.int32)
            mypos = jnp.take_along_axis(jnp.cumsum(oh, 1) - 1, fe[..., None], -1)[..., 0]
        return float((fw * (mypos < cap)).sum())

    assert kept_mass(cfg_c) >= kept_mass(cfg_a)

    # no pressure -> identical outputs
    cfg_c8 = _cfg(cf=8.0)
    cfg_a8 = dataclasses.replace(
        cfg_c8, moe=dataclasses.replace(cfg_c8.moe, dispatch_order="arrival"))
    o1, _ = moe.apply(params, cfg_c8, x)
    o2, _ = moe.apply(params, cfg_a8, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
