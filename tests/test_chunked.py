"""Chunked stepping engine: bit-exactness against the ``check_every=1``
per-cycle reference for every registered policy, under all three execution
engines (solo, batched sweep, sharded), plus the fused Pallas select path.

The chunked engine's correctness argument is that a completed overlay is a
fixed point of the cycle function and the exact completion cycle is repaired
from the per-cycle done trace — these tests pin that argument down for every
policy, several chunk depths (including one that doesn't divide the run
length), heterogeneous cycle budgets, and the cross-shard reduction path.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import schedulers
from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro.core.overlay import OverlayConfig, simulate, simulate_batch
from repro.core.partition import build_graph_memory

ALL_POLICIES = sorted(schedulers.REGISTRY)
CHECK_EVERYS = (1, 7, 32)


def _gm(sched, nx=2, ny=2):
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    policy = schedulers.get(sched)
    return build_graph_memory(g, nx, ny,
                              criticality_order=policy.wants_criticality_order)


def _stats(r):
    return (r.done, r.cycles, r.deflections, r.busy_cycles, r.delivered)


@pytest.fixture(scope="module")
def reference_runs():
    """check_every=1 reference result per policy (compiled once per policy)."""
    out = {}
    for sched in ALL_POLICIES:
        gm = _gm(sched)
        out[sched] = simulate(gm, OverlayConfig(
            scheduler=sched, max_cycles=500_000, check_every=1))
        assert out[sched].done
    return out


@pytest.mark.parametrize("check_every", CHECK_EVERYS)
@pytest.mark.parametrize("sched", ALL_POLICIES)
def test_simulate_chunked_bit_identical(sched, check_every, reference_runs):
    gm = _gm(sched)
    r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=500_000,
                                   check_every=check_every))
    ref = reference_runs[sched]
    assert _stats(r) == _stats(ref), (sched, check_every)
    np.testing.assert_array_equal(r.values, ref.values)


def test_autotuned_check_every_bit_identical(reference_runs):
    for sched in ALL_POLICIES:
        gm = _gm(sched)
        r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=500_000))
        assert _stats(r) == _stats(reference_runs[sched]), sched
        np.testing.assert_array_equal(r.values, reference_runs[sched].values)


@pytest.mark.parametrize("check_every", CHECK_EVERYS)
def test_simulate_batch_chunked_bit_identical(check_every):
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    gm = build_graph_memory(g, 4, 4, criticality_order=True)
    cfgs = [OverlayConfig(scheduler=p, max_cycles=500_000,
                          check_every=check_every) for p in ALL_POLICIES]
    # heterogeneous budget: freezes mid-chunk at its OWN max_cycles
    cfgs.append(OverlayConfig(scheduler="scan", max_cycles=100,
                              check_every=check_every))
    # an element that finishes long before the others keeps re-entering
    # chunks; its repaired cycle count must not drift
    cfgs.append(OverlayConfig(scheduler="ooo", select_latency=4,
                              max_cycles=500_000, check_every=check_every))
    for cfg, rb in zip(cfgs, simulate_batch(gm, cfgs)):
        rs = simulate(gm, OverlayConfig(
            scheduler=cfg.scheduler, select_latency=cfg.select_latency,
            max_cycles=cfg.max_cycles, check_every=1))
        assert _stats(rb) == _stats(rs), (cfg.scheduler, check_every)
        np.testing.assert_array_equal(rb.values, rs.values)


def test_batch_budget_on_chunk_boundary_is_exact():
    # Regression: an element whose max_cycles is an exact multiple of
    # check_every exhausts its budget precisely at a chunk boundary; it is
    # NOT a fixed point of the cycle function, so it must drop out of the
    # guard-free chunked phase instead of silently simulating on while the
    # longer-running element keeps chunking.
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    gm = build_graph_memory(g, 4, 4, criticality_order=True)
    cfgs = [OverlayConfig(scheduler="scan", max_cycles=98, check_every=7),
            OverlayConfig(scheduler="ooo", max_cycles=500_000, check_every=7)]
    for cfg, rb in zip(cfgs, simulate_batch(gm, cfgs)):
        rs = simulate(gm, OverlayConfig(
            scheduler=cfg.scheduler, max_cycles=cfg.max_cycles, check_every=1))
        assert _stats(rb) == _stats(rs), cfg.scheduler
        np.testing.assert_array_equal(rb.values, rs.values)


def test_chunk_boundary_never_overshoots_budget():
    # max_cycles deliberately NOT a multiple of check_every: the freeze guard
    # must stop the cycle counter exactly at the budget.
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    r = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=101,
                                   check_every=32))
    ref = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=101,
                                     check_every=1))
    assert not r.done and not ref.done
    assert _stats(r) == _stats(ref)
    np.testing.assert_array_equal(r.values, ref.values)


def test_check_every_zero_rejected():
    with pytest.raises(ValueError, match="check_every"):
        OverlayConfig(check_every=0)


def test_sharded_chunked_bit_identical():
    import jax

    from repro.core.distributed import simulate_sharded

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = wl.arrow_lu_graph(2, 5, 3, seed=4)
    ref_vals = reference_evaluate(g)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    for sched in ALL_POLICIES:
        ref = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=500_000,
                                         check_every=1))
        for check_every in (8, None):
            r = simulate_sharded(gm, mesh, OverlayConfig(
                scheduler=sched, max_cycles=500_000, check_every=check_every))
            assert _stats(r) == _stats(ref), (sched, check_every)
        np.testing.assert_array_equal(r.values, ref_vals)


def test_simulate_batch_sharded_matches_serial():
    import jax

    from repro.core.distributed import simulate_batch_sharded

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = wl.arrow_lu_graph(2, 5, 3, seed=4)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    cfgs = [OverlayConfig(scheduler=p, max_cycles=500_000)
            for p in ALL_POLICIES]
    cfgs.append(OverlayConfig(scheduler="scan", max_cycles=60))
    for cfg, rb in zip(cfgs, simulate_batch_sharded(gm, mesh, cfgs)):
        rs = simulate(gm, OverlayConfig(scheduler=cfg.scheduler,
                                        max_cycles=cfg.max_cycles,
                                        check_every=1))
        assert _stats(rb) == _stats(rs), cfg.scheduler
        np.testing.assert_array_equal(rb.values, rs.values)


@pytest.mark.parametrize("sched", ["ooo", "scan", "lru_flat"])
def test_select_engine_bit_identical(sched):
    # interpret=True on CPU: same fused kernels the TPU path compiles
    g_small = wl.layered_dag(4, 6, seed=3)
    gm_small = build_graph_memory(
        g_small, 2, 2,
        criticality_order=schedulers.get(sched).wants_criticality_order)
    ref = simulate(gm_small, OverlayConfig(scheduler=sched, check_every=1))
    r = simulate(gm_small, OverlayConfig(scheduler=sched, check_every=1,
                                         engine="select"))
    assert _stats(r) == _stats(ref), sched
    np.testing.assert_array_equal(r.values, ref.values)


def test_select_engine_batched_bit_identical():
    # the Pallas kernels must also batch correctly under the vmapped engine
    g = wl.layered_dag(4, 6, seed=3)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    cfgs = [OverlayConfig(scheduler=p, engine="select", max_cycles=100_000)
            for p in ("ooo", "scan")]
    for cfg, rb in zip(cfgs, simulate_batch(gm, cfgs)):
        rs = simulate(gm, OverlayConfig(scheduler=cfg.scheduler,
                                        max_cycles=100_000, check_every=1))
        assert _stats(rb) == _stats(rs), cfg.scheduler
        np.testing.assert_array_equal(rb.values, rs.values)


def test_simulate_batch_rejects_mixed_engine():
    g = wl.reduction_tree(16)
    gm = build_graph_memory(g, 2, 2)
    with pytest.raises(ValueError, match="engine"):
        simulate_batch(gm, [OverlayConfig(engine="jnp"),
                            OverlayConfig(engine="select")])


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.core import workloads as wl
from repro.core.partition import build_graph_memory
from repro.core.overlay import OverlayConfig, simulate
from repro.core.distributed import simulate_sharded, simulate_batch_sharded
mesh = jax.make_mesh((2, 4), ("data", "model"))
g = wl.arrow_lu_graph(4, 8, 6, seed=2)
gm = build_graph_memory(g, 4, 8, criticality_order=True)
ref = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=500000, check_every=1))
r = simulate_sharded(gm, mesh, OverlayConfig(scheduler="ooo", max_cycles=500000, check_every=7))
assert r.done and r.cycles == ref.cycles, (r.cycles, ref.cycles)
assert (r.deflections, r.busy_cycles, r.delivered) == (
    ref.deflections, ref.busy_cycles, ref.delivered)
np.testing.assert_array_equal(r.values, ref.values)
cfgs = [OverlayConfig(scheduler="ooo", max_cycles=500000),
        OverlayConfig(scheduler="inorder", max_cycles=500000),
        OverlayConfig(scheduler="scan", max_cycles=200)]
for cfg, b in zip(cfgs, simulate_batch_sharded(gm, mesh, cfgs)):
    s = simulate(gm, OverlayConfig(scheduler=cfg.scheduler,
                                   max_cycles=cfg.max_cycles, check_every=1))
    assert (b.done, b.cycles, b.deflections, b.busy_cycles) == (
        s.done, s.cycles, s.deflections, s.busy_cycles), cfg.scheduler
    np.testing.assert_array_equal(b.values, s.values)
print("CHUNKED_SHARDED_OK")
"""


@pytest.mark.slow
def test_chunked_sharded_multidevice_exact():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=os.getcwd(), capture_output=True, text=True,
                         env=env, timeout=420)
    assert "CHUNKED_SHARDED_OK" in out.stdout, out.stderr[-2000:]
