"""Optional-hypothesis shim.

``hypothesis`` is an optional dev dependency (see pyproject.toml). Test
modules that mix property-based and plain tests import ``given``/``settings``
/``st`` from here instead of from hypothesis directly: when hypothesis is
installed the real objects pass through; when it is missing, each ``@given``
test is skipped while the module's plain tests still collect and run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies.*`` lookups; never actually draws."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco
