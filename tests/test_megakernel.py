"""Megakernel chunk engine (``OverlayConfig(engine="megakernel")``):
interpret-mode oracle tests against the pure-jnp reference for every
registered policy x chunk depth x execution engine, the single-dispatch
lowering guarantee, the engine-aware ``check_every`` autotune, and the
removal of the old ``use_pallas`` spelling (``engine=`` is the only knob).

The megakernel's correctness argument is that its in-kernel body is the
*same* cycle function the reference engine scans, carried across the chunk
in kernel refs, with the identical done-trace repair applied to the kernel
outputs — so every cycle count, stat counter, and node value must reproduce
bit-for-bit (no tolerance anywhere in this file).
"""
import warnings

import numpy as np
import pytest

from repro.core import schedulers
from repro.core import workloads as wl
from repro.core.overlay import (OverlayConfig, resolve_check_every, simulate,
                                simulate_batch)
from repro.core.partition import build_graph_memory

ALL_POLICIES = sorted(schedulers.REGISTRY)
CHECK_EVERYS = (1, 8, 32)


def _gm(sched, nx=2, ny=2):
    g = wl.layered_dag(4, 6, seed=3)
    policy = schedulers.get(sched)
    return build_graph_memory(g, nx, ny,
                              criticality_order=policy.wants_criticality_order)


def _stats(r):
    return (r.done, r.cycles, r.deflections, r.busy_cycles, r.delivered)


@pytest.fixture(scope="module")
def reference_runs():
    """check_every=1 pure-jnp reference result per policy."""
    out = {}
    for sched in ALL_POLICIES:
        out[sched] = simulate(_gm(sched), OverlayConfig(
            scheduler=sched, max_cycles=100_000, check_every=1))
        assert out[sched].done
    return out


@pytest.mark.parametrize("check_every", CHECK_EVERYS)
@pytest.mark.parametrize("sched", ALL_POLICIES)
def test_megakernel_bit_identical(sched, check_every, reference_runs):
    r = simulate(_gm(sched), OverlayConfig(
        scheduler=sched, max_cycles=100_000, check_every=check_every,
        engine="megakernel"))
    ref = reference_runs[sched]
    assert _stats(r) == _stats(ref), (sched, check_every)
    np.testing.assert_array_equal(r.values, ref.values)


@pytest.mark.parametrize("check_every", CHECK_EVERYS)
def test_megakernel_batched_bit_identical(check_every):
    g = wl.layered_dag(4, 6, seed=3)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    cfgs = [OverlayConfig(scheduler=p, max_cycles=100_000,
                          check_every=check_every, engine="megakernel")
            for p in ALL_POLICIES]
    # heterogeneous budget: freezes mid-chunk at its OWN max_cycles
    cfgs.append(OverlayConfig(scheduler="scan", max_cycles=20,
                              check_every=check_every, engine="megakernel"))
    for cfg, rb in zip(cfgs, simulate_batch(gm, cfgs)):
        rs = simulate(gm, OverlayConfig(
            scheduler=cfg.scheduler, max_cycles=cfg.max_cycles, check_every=1))
        assert _stats(rb) == _stats(rs), (cfg.scheduler, check_every)
        np.testing.assert_array_equal(rb.values, rs.values)


def test_megakernel_sharded_bit_identical():
    import jax

    from repro.core.distributed import simulate_batch_sharded, simulate_sharded

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = wl.layered_dag(4, 6, seed=3)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    ref = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=100_000,
                                     check_every=1))
    r = simulate_sharded(gm, mesh, OverlayConfig(
        scheduler="ooo", max_cycles=100_000, check_every=8,
        engine="megakernel"))
    assert _stats(r) == _stats(ref)
    np.testing.assert_array_equal(r.values, ref.values)
    cfgs = [OverlayConfig(scheduler=p, max_cycles=100_000, engine="megakernel")
            for p in ("ooo", "scan")]
    for cfg, rb in zip(cfgs, simulate_batch_sharded(gm, mesh, cfgs)):
        rs = simulate(gm, OverlayConfig(scheduler=cfg.scheduler,
                                        max_cycles=100_000, check_every=1))
        assert _stats(rb) == _stats(rs), cfg.scheduler
        np.testing.assert_array_equal(rb.values, rs.values)


def _top_level_primitives(fn, *args):
    import jax

    return [eqn.primitive.name for eqn in jax.make_jaxpr(fn)(*args).jaxpr.eqns]


@pytest.mark.parametrize("sched", ALL_POLICIES)
def test_megakernel_chunk_is_single_pallas_call(sched):
    # The fused chunk must lower to exactly ONE pallas_call dispatch region:
    # no lax.scan of per-cycle dispatches, no second kernel for the
    # scheduler select — the whole K-cycle carry lives inside the kernel.
    from repro.core.overlay import device_graph, init_state, make_engine_chunk_fn

    cfg = OverlayConfig(scheduler=sched, engine="megakernel")
    g = device_graph(_gm(sched))
    state = init_state(g, cfg)
    chunk = make_engine_chunk_fn(g, cfg, 8)
    prims = _top_level_primitives(chunk, state)
    assert prims.count("pallas_call") == 1, prims
    assert "scan" not in prims and "while" not in prims, prims


def test_jnp_chunk_is_not_fused():
    # Contrast case: the reference engine's chunk really is a scanned body —
    # proof the single-dispatch assertion above is measuring fusion, not a
    # vacuous property of the tracer.
    from repro.core.overlay import device_graph, init_state, make_engine_chunk_fn

    cfg = OverlayConfig(scheduler="ooo")
    g = device_graph(_gm("ooo"))
    state = init_state(g, cfg)
    prims = _top_level_primitives(make_engine_chunk_fn(g, cfg, 8), state)
    assert "scan" in prims
    assert "pallas_call" not in prims


def test_resolve_check_every_keys_on_engine():
    # Small graph on CPU: jnp autotunes shallow, the select engine at least
    # 16 (one Pallas dispatch per cycle), the megakernel always 32 (one
    # kernel launch per chunk amortizes with depth).
    nx = ny = 2
    L = 32
    kw = dict(backend="cpu", num_devices=1)
    assert resolve_check_every(OverlayConfig(), nx, ny, L, **kw) == 8
    assert resolve_check_every(
        OverlayConfig(engine="select"), nx, ny, L, **kw) == 16
    assert resolve_check_every(
        OverlayConfig(engine="megakernel"), nx, ny, L, **kw) == 32
    # explicit check_every always wins over the engine keying
    assert resolve_check_every(
        OverlayConfig(engine="megakernel", check_every=4), nx, ny, L, **kw) == 4
    # multi-device keying unchanged
    assert resolve_check_every(OverlayConfig(), nx, ny, L, backend="cpu",
                               num_devices=8) == 32


def test_use_pallas_removed():
    # the shim is gone: engine= is the only spelling
    with pytest.raises(TypeError):
        OverlayConfig(use_pallas=True)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        OverlayConfig(engine="select")
        OverlayConfig(engine="megakernel")
    assert not caught
    with pytest.raises(ValueError, match="engine"):
        OverlayConfig(engine="turbo")


def test_simulate_batch_rejects_mixed_engine():
    g = wl.reduction_tree(16)
    gm = build_graph_memory(g, 2, 2)
    with pytest.raises(ValueError, match="engine"):
        simulate_batch(gm, [OverlayConfig(engine="jnp"),
                            OverlayConfig(engine="megakernel")])
