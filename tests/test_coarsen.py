"""Multilevel placement (repro.place.coarsen): clustering determinism and
size caps, quotient-table weight conservation, identity-coarsened anneal ==
the PR-3 annealer bit-exactly, uncoarsened placements are valid node -> PE
maps, and the workloads graph cache round-trips."""
import os

import numpy as np
import pytest

from repro import place
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig
from repro.place.cost import edge_tables

G = wl.arrow_lu_graph(3, 6, 4, seed=5)

ACFG = place.AnnealConfig(replicas=6, rounds=10, steps=192, seed=0)


# ---------------------------------------------------------------------------
# Clustering.
# ---------------------------------------------------------------------------

def test_cluster_nodes_deterministic_capped_compact():
    c1 = place.cluster_nodes(G, 16)
    c2 = place.cluster_nodes(G, 16)
    np.testing.assert_array_equal(c1, c2)
    sizes = np.bincount(c1)
    assert sizes.max() <= 16 and sizes.min() >= 1
    # Dense ids 0..C-1, first-appearance order.
    assert c1.min() == 0 and set(np.unique(c1)) == set(range(c1.max() + 1))
    first_seen = [c1[np.argmax(c1 == k)] for k in range(c1.max() + 1)]
    assert first_seen == sorted(first_seen)
    # A real reduction: at least 4x fewer clusters than nodes at ratio 16.
    assert (c1.max() + 1) * 4 <= G.num_nodes


def test_cluster_ratio_one_is_identity():
    np.testing.assert_array_equal(place.cluster_nodes(G, 1),
                                  np.arange(G.num_nodes))
    with pytest.raises(ValueError, match="ratio"):
        place.cluster_nodes(G, 0)


def test_quotient_tables_conserve_weight():
    clusters = place.cluster_nodes(G, 8)
    csrc, cdst, cw_edge, cw_node = place.quotient_tables(G, clusters)
    src, dst, w_edge, w_node = edge_tables(G)
    assert int(cw_node.sum()) == int(w_node.sum())
    cross = clusters[src] != clusters[dst]
    assert int(cw_edge.sum()) == int(w_edge[cross].sum())
    assert (csrc != cdst).all()
    c = int(clusters.max()) + 1
    assert csrc.max(initial=0) < c and cdst.max(initial=0) < c


# ---------------------------------------------------------------------------
# Multilevel pipeline.
# ---------------------------------------------------------------------------

def test_identity_coarsen_matches_plain_annealer_bit_exactly():
    plain = place.anneal_placement(G, 4, 4, ACFG)
    ml = place.multilevel_anneal(G, 4, 4, ACFG,
                                 clusters=np.arange(G.num_nodes), refine=None)
    np.testing.assert_array_equal(ml.node_pe, plain.node_pe)
    assert ml.coarse.cost == plain.cost
    assert ml.num_clusters == G.num_nodes


def test_uncoarsened_placement_is_valid_and_cluster_consistent():
    ml = place.multilevel_anneal(G, 4, 4, ACFG, ratio=16, refine=None)
    assert ml.node_pe.shape == (G.num_nodes,)
    assert ml.node_pe.dtype == np.int32
    assert ml.node_pe.min() >= 0 and ml.node_pe.max() < 16
    # Without refinement every node sits on its cluster's PE.
    np.testing.assert_array_equal(ml.node_pe,
                                  ml.coarse.node_pe[ml.clusters])
    # And the packed memory accepts it (valid node -> PE map end to end).
    gm = place.graph_memory(G, 4, 4, ml.node_pe)
    assert gm.num_nodes == G.num_nodes


def test_multilevel_deterministic_and_refine_never_worse():
    a = place.multilevel_anneal(G, 4, 4, ACFG, ratio=16, refine=ACFG)
    b = place.multilevel_anneal(G, 4, 4, ACFG, ratio=16, refine=ACFG)
    np.testing.assert_array_equal(a.node_pe, b.node_pe)
    assert a.cost == b.cost
    # Refinement warm-starts from the projection and tracks best-so-far.
    assert a.cost <= a.projected_cost
    assert a.refined is not None and a.refined.init_cost == a.projected_cost


def test_multilevel_spec_threads_through_resolve():
    spec = place.PlacementSpec(strategy="multilevel", anneal=ACFG,
                               coarsen_ratio=16, refine=ACFG)
    via_spec = place.resolve(G, 4, 4, spec)
    direct = place.multilevel_anneal(G, 4, 4, ACFG, ratio=16, refine=ACFG)
    np.testing.assert_array_equal(via_spec, direct.node_pe)
    with pytest.raises(ValueError, match="coarsen_ratio"):
        place.PlacementSpec(strategy="multilevel", coarsen_ratio=0)


def test_multilevel_beats_round_robin_on_cycles():
    g = wl.arrow_lu_graph(2, 8, 6, seed=3)
    ml = place.multilevel_anneal(
        g, 8, 8, place.AnnealConfig(replicas=8, rounds=16, steps=384, seed=0),
        ratio=8,
        refine=place.AnnealConfig(replicas=6, rounds=12, steps=512, seed=0))
    res = place.evaluate_placements(g, 8, 8, {
        "round_robin": "round_robin", "multilevel": ml.node_pe,
    }, cfgs=OverlayConfig(max_cycles=500_000))
    assert res["round_robin"].done and res["multilevel"].done
    assert res["multilevel"].cycles < res["round_robin"].cycles


# ---------------------------------------------------------------------------
# Workloads graph cache (fig1_full satellite).
# ---------------------------------------------------------------------------

def test_cached_graph_roundtrip(tmp_path):
    calls = []

    def build():
        calls.append(1)
        return G

    a = wl.cached_graph("t", build, cache_dir=str(tmp_path))
    b = wl.cached_graph("t", build, cache_dir=str(tmp_path))
    assert calls == [1]                     # second call served from disk
    assert os.path.exists(tmp_path / "t.npz")
    for f in ("opcode", "fanout_ptr", "fanout_dst", "fanout_slot",
              "initial_values"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    b.validate()


def test_fig1_full_calibration_small(tmp_path):
    # Same constructor, tiny budget: must land near the target and cache.
    g1 = wl.fig1_full(target_nodes=1_000, seed=0, cache_dir=str(tmp_path))
    g2 = wl.fig1_full(target_nodes=1_000, seed=0, cache_dir=str(tmp_path))
    np.testing.assert_array_equal(g1.opcode, g2.opcode)
    assert 500 <= g1.num_nodes <= 20_000    # lu_size_for_nodes is heuristic
    assert len(list(tmp_path.iterdir())) == 1
