"""benchmarks/check_bench.py — the gate that guards every tracked cycle
count — exercised directly: exit codes for regressed cycles, vanished rows,
below-floor Spearman, guided-annealing floor violations, and the
informational-only treatment of wall-time deltas."""
import copy
import importlib.util
import json
import pathlib

import pytest

_CHECK = (pathlib.Path(__file__).resolve().parents[1]
          / "benchmarks" / "check_bench.py")
_spec = importlib.util.spec_from_file_location("check_bench", _CHECK)
cb = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cb)


def _bench():
    """A minimal snapshot touching every gated section shape."""
    return {
        "fig1": [{"name": "fig1_arrow_n100", "wall_s": 1.0,
                  "cycles_per_sec": 1000.0,
                  "cycles_ooo": 120, "cycles_inorder": 150}],
        "policy_sweep": {"schedulers": [
            {"scheduler": "ooo", "cycles": 120},
            {"scheduler": "inorder", "cycles": 150}]},
        "chunking": {"rows": [{"name": "chunking_auto_n100", "wall_s": 0.5,
                               "cycles": {"ooo": 120}}]},
        "placement": {"rows": [{"name": "placement_a", "wall_s": 2.0,
                                "cycles_identity": 100,
                                "cycles_annealed": 80}]},
        "eject": {"rows": []},
        "surrogate": {"rows": [
            {"name": "surrogate_a", "wall_s": 3.0, "spearman": 0.95,
             "prune_gap": 1.0, "pruned_best": 80, "exhaustive_best": 80},
            {"name": "surrogate_multilevel_n100", "wall_s": 4.0,
             "cycles_round_robin": 140, "cycles_multilevel": 90}]},
        "guided": {"rows": [
            {"name": "guided_a", "wall_s": 5.0,
             "cycles_unguided": 80, "cycles_guided": 75,
             "cost_evals": 30, "cost_evals_unguided": 100,
             "eval_ratio": 0.3}]},
        "fig1_full": {"rows": [
            {"name": "fig1_full_n470000", "wall_s": 60.0,
             "cycles_round_robin": 40000, "cycles_multilevel": 25000}]},
        "telemetry": {"rows": [
            {"name": "telemetry_arrow_n100_ooo", "wall_s": 6.0,
             "cycles_ooo": 120, "ctr_stall_no_ready": 5000,
             "ctr_noc_deflections": 300, "link_util_p50": 0.4}]},
    }


def _run(tmp_path, baseline, fresh):
    b = tmp_path / "baseline.json"
    f = tmp_path / "fresh.json"
    b.write_text(json.dumps(baseline))
    f.write_text(json.dumps(fresh))
    return cb.main(str(b), str(f))


def test_identical_snapshots_pass(tmp_path, capsys):
    assert _run(tmp_path, _bench(), _bench()) == 0
    out = capsys.readouterr().out
    assert "OK" in out and "no regressions" in out


def test_cycle_regression_fails(tmp_path, capsys):
    fresh = _bench()
    fresh["fig1"][0]["cycles_ooo"] = 121
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "cycle-count regression" in capsys.readouterr().out


def test_improvement_passes_and_is_reported(tmp_path, capsys):
    fresh = _bench()
    fresh["placement"]["rows"][0]["cycles_annealed"] = 70
    assert _run(tmp_path, _bench(), fresh) == 0
    assert "BETTER" in capsys.readouterr().out


def test_vanished_cycle_row_fails(tmp_path, capsys):
    fresh = _bench()
    fresh["placement"]["rows"] = []
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "missing from fresh run" in capsys.readouterr().out


def test_vanished_fig1_full_row_fails(tmp_path):
    fresh = _bench()
    del fresh["fig1_full"]
    assert _run(tmp_path, _bench(), fresh) == 1


def test_new_rows_are_informational(tmp_path, capsys):
    fresh = _bench()
    fresh["fig1"].append({"name": "fig1_arrow_n200", "wall_s": 2.0,
                          "cycles_ooo": 300})
    assert _run(tmp_path, _bench(), fresh) == 0
    assert "NEW" in capsys.readouterr().out


def test_wall_time_deltas_never_block(tmp_path, capsys):
    fresh = _bench()
    for row in (fresh["fig1"] + fresh["placement"]["rows"]
                + fresh["guided"]["rows"]):
        row["wall_s"] = 1000.0      # 100x slower: noisy-runner territory
    fresh["fig1"][0]["cycles_per_sec"] = 1.0
    assert _run(tmp_path, _bench(), fresh) == 0
    assert "WALL" in capsys.readouterr().out


def test_below_floor_spearman_fails(tmp_path, capsys):
    fresh = _bench()
    fresh["surrogate"]["rows"][0]["spearman"] = cb.SPEARMAN_FLOOR - 0.01
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "spearman" in capsys.readouterr().out


def test_prune_gap_above_max_fails(tmp_path):
    fresh = _bench()
    fresh["surrogate"]["rows"][0]["prune_gap"] = cb.PRUNE_GAP_MAX + 0.01
    assert _run(tmp_path, _bench(), fresh) == 1


def test_vanished_quality_row_fails(tmp_path, capsys):
    # Rank rows carry no cycles_* keys, so only the quality check can
    # protect them from silently disappearing.
    fresh = _bench()
    fresh["surrogate"]["rows"] = [fresh["surrogate"]["rows"][1]]
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "quality row missing" in capsys.readouterr().out


def test_guided_eval_ratio_above_max_fails(tmp_path, capsys):
    fresh = _bench()
    fresh["guided"]["rows"][0].update(
        eval_ratio=cb.GUIDED_EVAL_RATIO_MAX + 0.01, cost_evals=51)
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "cost_evals" in capsys.readouterr().out


def test_guided_worse_than_unguided_fails(tmp_path, capsys):
    fresh = _bench()
    # Both cycle counts improve on baseline (no plain regression), but the
    # guided <= unguided relation breaks — must still fail.
    fresh["guided"]["rows"][0].update(cycles_unguided=70, cycles_guided=74)
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "guided" in capsys.readouterr().out


def test_guided_relation_checked_even_without_baseline(tmp_path):
    baseline = _bench()
    del baseline["guided"]      # first run that introduces the section
    fresh = _bench()
    fresh["guided"]["rows"][0].update(cost_evals=90, eval_ratio=0.9)
    assert _run(tmp_path, baseline, fresh) == 1


def test_guided_gate_uses_exact_counters_not_rounded_ratio(tmp_path):
    # eval_ratio rounds to exactly the max, but the integer counters are a
    # hairline over — the exact comparison must still fail.
    fresh = _bench()
    fresh["guided"]["rows"][0].update(
        cost_evals=50001, cost_evals_unguided=100000,
        eval_ratio=cb.GUIDED_EVAL_RATIO_MAX)
    assert _run(tmp_path, _bench(), fresh) == 1
    # ... and the display-only fallback still gates rows without counters.
    fresh2 = _bench()
    row = fresh2["guided"]["rows"][0]
    del row["cost_evals"], row["cost_evals_unguided"]
    row["eval_ratio"] = cb.GUIDED_EVAL_RATIO_MAX + 0.01
    assert _run(tmp_path, _bench(), fresh2) == 1


def test_telemetry_counter_drift_fails_both_directions(tmp_path, capsys):
    # Instrument counters are semantics, not perf: a *decrease* is just as
    # much drift as an increase, unlike cycle counts.
    for delta in (+1, -1):
        fresh = _bench()
        fresh["telemetry"]["rows"][0]["ctr_stall_no_ready"] += delta
        assert _run(tmp_path, _bench(), fresh) == 1
        assert "bit-exactly" in capsys.readouterr().out


def test_telemetry_cycles_still_gated_no_increase(tmp_path):
    fresh = _bench()
    fresh["telemetry"]["rows"][0]["cycles_ooo"] = 121
    assert _run(tmp_path, _bench(), fresh) == 1


def test_telemetry_floats_are_informational(tmp_path):
    # Utilization percentiles derive from wall-independent integers but are
    # rounded floats — only ctr_* keys carry the bit-exact contract.
    fresh = _bench()
    fresh["telemetry"]["rows"][0]["link_util_p50"] = 0.9
    assert _run(tmp_path, _bench(), fresh) == 0


def test_vanished_telemetry_row_fails(tmp_path, capsys):
    fresh = _bench()
    fresh["telemetry"]["rows"] = []
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "telemetry row missing" in capsys.readouterr().out


def test_vanished_ctr_key_fails(tmp_path, capsys):
    fresh = _bench()
    del fresh["telemetry"]["rows"][0]["ctr_noc_deflections"]
    assert _run(tmp_path, _bench(), fresh) == 1
    assert "ctr_noc_deflections" in capsys.readouterr().out


def test_bad_usage_exit_code():
    with pytest.raises(FileNotFoundError):
        cb.main("/nonexistent/a.json", "/nonexistent/b.json")


def test_deep_copy_safety():
    # _bench fixtures must be independent per test (guard the test file
    # itself against aliasing bugs).
    a, b = _bench(), _bench()
    a["fig1"][0]["cycles_ooo"] = 1
    assert b["fig1"][0]["cycles_ooo"] == 120
    assert copy.deepcopy(a) == a
