"""shard_map overlay: cycle-exact equivalence with the single-device sim
(subprocess with 8 fake host devices)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro.core.partition import build_graph_memory
from repro.core.overlay import OverlayConfig, simulate
from repro.core.distributed import simulate_sharded
mesh = jax.make_mesh((2, 4), ("data", "model"))
g = wl.arrow_lu_graph(4, 8, 6, seed=2)
ref = reference_evaluate(g)
gm = build_graph_memory(g, 4, 8, criticality_order=True)
r1 = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=500000))
r2 = simulate_sharded(gm, mesh, OverlayConfig(scheduler="ooo", max_cycles=500000))
assert r2.done and r1.cycles == r2.cycles, (r1.cycles, r2.cycles)
np.testing.assert_allclose(r2.values, ref, rtol=1e-5, atol=1e-5)
print("SHARDED_EXACT_OK")
"""


@pytest.mark.slow
def test_sharded_overlay_cycle_exact():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.getcwd(),
                         capture_output=True, text=True, env=env, timeout=420)
    assert "SHARDED_EXACT_OK" in out.stdout, out.stderr[-2000:]
