"""Surrogate subsystem (repro.surrogate): bit-reproducible fits, feature
batch/solo consistency, rank quality on the fig1 family, the pruning bridge
into place.evaluate_placements, and the recompile-churn fix (one compiled
program per candidate set)."""
import numpy as np
import pytest

from repro import place, surrogate
from repro.core import workloads as wl
from repro.core.overlay import OverlayConfig

#: small fig1-family graph: fast, but structured like the paper's workloads
G = wl.arrow_lu_graph(2, 6, 4, seed=5)
NX = NY = 4
CFG = OverlayConfig(max_cycles=200_000)


@pytest.fixture(scope="module")
def trained():
    """One shared (model, placements, cycles) fit for the module."""
    return surrogate.fit_from_sim(G, NX, NY, cfg=CFG, n_train=24, seed=0)


# ---------------------------------------------------------------------------
# Determinism: fixed key -> bit-identical training set and coefficients.
# ---------------------------------------------------------------------------

def test_sampling_deterministic_and_decorrelated():
    a = surrogate.sample_placements(G, NX, NY, 12, seed=0)
    b = surrogate.sample_placements(G, NX, NY, 12, seed=0)
    np.testing.assert_array_equal(a, b)
    c = surrogate.sample_placements(G, NX, NY, 12, seed=1)
    # Static-heuristic rows are seed-independent; the sampled tail must move.
    assert (a[5:] != c[5:]).any()
    assert a.dtype == np.int32 and a.min() >= 0 and a.max() < NX * NY


def test_fit_bit_identical_coefficients(trained):
    model, placements, cycles = trained
    refit = surrogate.fit(G, NX, NY, placements, cycles)
    np.testing.assert_array_equal(model.beta, refit.beta)
    np.testing.assert_array_equal(model.mu, refit.mu)
    np.testing.assert_array_equal(model.sigma, refit.sigma)
    assert model.y_mean == refit.y_mean


def test_features_batch_matches_solo():
    ext = surrogate.build_features(G, NX, NY)
    cands = surrogate.sample_placements(G, NX, NY, 6, seed=2)
    batch = ext.features_batch(cands)
    solo = np.stack([ext.features_batch(c[None])[0] for c in cands])
    np.testing.assert_array_equal(batch, solo)
    assert batch.shape == (6, ext.num_features)
    # Integer accumulations: the float64 features are exact integers.
    np.testing.assert_array_equal(batch, np.rint(batch))


def test_features_see_locality_and_balance():
    ext = surrogate.build_features(G, NX, NY)
    all_one = np.zeros(G.num_nodes, np.int32)
    spread = place.resolve(G, NX, NY, "round_robin")
    f_one = ext.features_batch(all_one[None])[0]
    f_spread = ext.features_batch(spread[None])[0]
    assert f_one[0] == 0                      # zero traffic when co-located
    assert f_spread[0] > 0
    assert f_one[3] > f_spread[3]             # piled load -> higher pressure


# ---------------------------------------------------------------------------
# Rank quality + the pruning bridge.
# ---------------------------------------------------------------------------

def test_rank_quality_held_out(trained):
    model, _, _ = trained
    held = surrogate.sample_placements(G, NX, NY, 24, seed=11)
    cycles = np.asarray(
        [r.cycles for r in place.simulate_placements(G, NX, NY, list(held),
                                                     CFG)])
    rho = surrogate.spearman(model.predict_batch(held), cycles)
    assert rho >= 0.7, f"held-out spearman {rho:.3f}"
    order = model.rank(held)
    assert sorted(order.tolist()) == list(range(24))


def test_prune_surrogate_simulates_only_top_k(trained):
    model, _, _ = trained
    cands = surrogate.sample_placements(G, NX, NY, 12, seed=3)
    names = {f"c{i}": p for i, p in enumerate(cands)}
    full = place.evaluate_placements(G, NX, NY, names, cfgs=CFG)
    pruned = place.evaluate_placements(G, NX, NY, names, cfgs=CFG,
                                       prune="surrogate", keep_top=3,
                                       surrogate=model)
    assert len(pruned) == 3 and set(pruned) <= set(full)
    for name, r in pruned.items():
        assert r.done
        assert r.cycles == full[name].cycles  # pruning never changes scoring
    with pytest.raises(ValueError, match="unknown prune mode"):
        place.evaluate_placements(G, NX, NY, names, cfgs=CFG, prune="oracle")


def test_wrong_graph_or_grid_rejected(trained):
    model, _, _ = trained
    other = wl.arrow_lu_graph(2, 8, 6, seed=3)      # different node count
    with pytest.raises(ValueError, match="extractor was built for"):
        model.predict_batch(np.zeros((2, other.num_nodes), np.int32))
    with pytest.raises(ValueError, match="outside the"):
        model.predict_batch(np.full(G.num_nodes, NX * NY, np.int32))


def test_spearman_helper():
    assert surrogate.spearman([1, 2, 3], [10, 20, 30]) == 1.0
    assert surrogate.spearman([1, 2, 3], [30, 20, 10]) == -1.0
    assert abs(surrogate.spearman([1, 1, 2], [1, 1, 2]) - 1.0) < 1e-12


# ---------------------------------------------------------------------------
# Recompile churn: one candidate set -> one compiled batch program.
# ---------------------------------------------------------------------------

def test_uniform_memories_share_shapes_and_one_compile():
    from repro.core.overlay import _run_batch_jit

    cands = surrogate.sample_placements(G, NX, NY, 5, seed=4)
    gms = place.uniform_graph_memories(G, NX, NY, list(cands))
    shapes = {(gm.lmax, gm.emax, gm.words) for gm in gms}
    assert len(shapes) == 1
    before = _run_batch_jit._cache_size()
    res = place.simulate_placements(G, NX, NY, list(cands), CFG)
    assert all(r.done for r in res)
    assert _run_batch_jit._cache_size() - before <= 1


def test_uniform_padding_is_result_invariant():
    # Padded memories must simulate bit-identically to naturally-sized ones.
    from repro.core.overlay import simulate

    pe = place.resolve(G, NX, NY, "clustered")
    gm_nat = place.graph_memory(G, NX, NY, pe)
    gm_pad = place.uniform_graph_memories(
        G, NX, NY, [pe, np.zeros(G.num_nodes, np.int32)])[0]
    assert gm_pad.lmax >= gm_nat.lmax and gm_pad.emax >= gm_nat.emax
    a = simulate(gm_nat, CFG)
    b = simulate(gm_pad, CFG)
    assert (a.cycles, a.done, a.delivered) == (b.cycles, b.done, b.delivered)
    np.testing.assert_array_equal(a.values, b.values)


def test_scan_policy_skips_lmax_padding():
    # The scan policy models select latency from the RDY word count, so
    # padding the slot depth would change cycle counts — evaluate_placements
    # must fall back to per-placement depths for it.
    from repro.core.overlay import simulate

    cfg = OverlayConfig(scheduler="scan", max_cycles=500_000)
    pe = place.resolve(G, NX, NY, "clustered")
    res = place.evaluate_placements(
        G, NX, NY, {"clustered": pe, "one_pe": np.zeros(G.num_nodes, np.int32)},
        cfgs=cfg)
    ref = simulate(G, cfg, nx=NX, ny=NY)  # identity via the engine path
    solo = simulate(place.graph_memory(
        G, NX, NY, pe,
        criticality_order=False), cfg)
    assert res["clustered"].cycles == solo.cycles
    assert ref.done and res["one_pe"].done
