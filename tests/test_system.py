"""End-to-end behaviour tests for the paper's system.

The headline claims, as executable assertions:
  1. OoO criticality scheduling beats in-order FCFS on large mixed
     factorization graphs (Fig. 1 regime) while computing identical values.
  2. The RDY-flag memory model reproduces the ~6% overhead and the ~5x
     capacity gain from FIFO elimination (Table I / §III).
  3. The LM stack trains end-to-end and serves with cache consistency
     (framework integration).
"""
import numpy as np

from repro.core import partition as pt
from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro.core.overlay import OverlayConfig, simulate
from repro.core.partition import build_graph_memory


def test_ooo_beats_inorder_at_scale():
    g = wl.arrow_lu_graph(16, 10, 8, seed=3)   # ~59K nodes
    ref = reference_evaluate(g)
    cycles = {}
    for sched in ("ooo", "inorder"):
        gm = build_graph_memory(g, 16, 16, criticality_order=(sched == "ooo"))
        r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=4_000_000))
        assert r.done
        np.testing.assert_allclose(r.values, ref, rtol=1e-5, atol=1e-5)
        cycles[sched] = r.cycles
    speedup = cycles["inorder"] / cycles["ooo"]
    assert speedup > 1.05, f"OoO speedup {speedup:.3f} <= 1.05"


def test_small_graphs_no_ooo_benefit():
    """Paper Fig. 1: below ~30K nodes the schedulers are comparable."""
    g = wl.arrow_lu_graph(2, 8, 4, seed=1)
    cycles = {}
    for sched in ("ooo", "inorder"):
        gm = build_graph_memory(g, 16, 16, criticality_order=(sched == "ooo"))
        r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=1_000_000))
        cycles[sched] = r.cycles
    ratio = cycles["inorder"] / cycles["ooo"]
    assert 0.7 < ratio < 1.3


def test_memory_model_reproduces_paper():
    assert pt.rdy_flag_overhead() == 0.0625  # "~6%"
    ino = pt.capacity_elements(256, "inorder")
    ooo = pt.capacity_elements(256, "ooo")
    assert 80_000 <= ino["elements"] <= 130_000      # "~100K nodes and edges"
    ratio = ooo["elements"] / ino["elements"]
    # Model lower-bound is exactly 3.75x (words ratio 3840/1024); the paper's
    # "~5x" additionally needs FIFO entries wider than one 40b word or
    # power-of-2 banking fragmentation — see EXPERIMENTS.md §Table1.
    assert 3.5 <= ratio <= 6.0


def test_criticality_ordering_matters():
    """OoO with criticality-sorted memory beats OoO with id-ordered memory
    (isolates the paper's static-labeling contribution)."""
    g = wl.arrow_lu_graph(16, 10, 8, seed=4)
    cycles = {}
    for crit in (True, False):
        gm = build_graph_memory(g, 16, 16, criticality_order=crit)
        r = simulate(gm, OverlayConfig(scheduler="ooo", max_cycles=4_000_000))
        assert r.done
        cycles[crit] = r.cycles
    assert cycles[True] < cycles[False]
