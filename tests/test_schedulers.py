"""Scheduler subsystem: registry contract, policy equivalence (every policy
computes exactly the graph-level reference values), OoO superiority on
criticality-heavy workloads, and batched-sweep == serial cycle exactness."""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro.core.overlay import OverlayConfig, simulate, simulate_batch
from repro.core.partition import build_graph_memory
from repro.core import schedulers

ALL_POLICIES = sorted(schedulers.REGISTRY)


def _run(g, nx, ny, sched, **kw):
    policy = schedulers.get(sched)
    gm = build_graph_memory(g, nx, ny,
                            criticality_order=policy.wants_criticality_order)
    cfg = OverlayConfig(scheduler=sched, max_cycles=500_000, **kw)
    return simulate(gm, cfg)


def test_registry_contract():
    assert set(schedulers.REGISTRY) >= {"ooo", "inorder", "scan", "lru_flat"}
    for name, policy in schedulers.REGISTRY.items():
        assert policy.name == name
        assert schedulers.get(name) is policy
    with pytest.raises(ValueError, match="unknown scheduler"):
        schedulers.get("nope")


@pytest.mark.parametrize("sched", ALL_POLICIES)
def test_every_policy_matches_reference_sparse_lu(sched):
    g = wl.sparse_lu_graph(10, 0.35, seed=7)
    ref = reference_evaluate(g)
    r = _run(g, 2, 2, sched)
    assert r.done
    np.testing.assert_array_equal(r.values, ref)  # bit-identical


@given(st.integers(3, 7), st.integers(4, 10), st.integers(0, 1_000),
       st.sampled_from(ALL_POLICIES))
@settings(max_examples=12, deadline=None)
def test_every_policy_matches_reference_layered(layers, width, seed, sched):
    g = wl.layered_dag(layers, width, seed=seed)
    ref = reference_evaluate(g)
    r = _run(g, 2, 2, sched)
    assert r.done
    np.testing.assert_array_equal(r.values, ref)  # bit-identical


def test_ooo_beats_inorder_on_arrow_lu():
    g = wl.arrow_lu_graph(4, 8, 6, seed=2)
    ooo = _run(g, 4, 4, "ooo")
    ino = _run(g, 4, 4, "inorder")
    assert ooo.done and ino.done
    assert ooo.cycles <= ino.cycles


def test_all_policies_terminate_on_16x16_grid():
    g = wl.arrow_lu_graph(4, 6, 4, seed=1)
    ref = reference_evaluate(g)
    gm = build_graph_memory(g, 16, 16, criticality_order=True)
    for sched in ALL_POLICIES:
        r = simulate(gm, OverlayConfig(scheduler=sched, max_cycles=500_000))
        assert r.done, sched
        np.testing.assert_array_equal(r.values, ref)


def test_simulate_batch_matches_serial():
    g = wl.arrow_lu_graph(3, 6, 4, seed=5)
    gm = build_graph_memory(g, 4, 4, criticality_order=True)
    cfgs = [OverlayConfig(scheduler=p, max_cycles=500_000) for p in ALL_POLICIES]
    cfgs.append(OverlayConfig(scheduler="ooo", select_latency=4,
                              max_cycles=500_000))
    # heterogeneous cycle budget: must freeze at its OWN max_cycles, done=False
    cfgs.append(OverlayConfig(scheduler="scan", max_cycles=100))
    batch = simulate_batch(gm, cfgs)
    assert len(batch) == len(cfgs)
    for cfg, rb in zip(cfgs, batch):
        rs = simulate(gm, cfg)
        assert rb.done == rs.done
        assert rb.cycles == rs.cycles, cfg
        assert rb.delivered == rs.delivered
        assert rb.busy_cycles == rs.busy_cycles
        np.testing.assert_array_equal(rb.values, rs.values)


def test_simulate_batch_rejects_mixed_eject_capacity():
    g = wl.reduction_tree(16)
    gm = build_graph_memory(g, 2, 2)
    with pytest.raises(ValueError, match="eject_capacity"):
        simulate_batch(gm, [OverlayConfig(eject_capacity=1),
                            OverlayConfig(eject_capacity=2)])


def test_simulate_batch_empty():
    g = wl.reduction_tree(8)
    gm = build_graph_memory(g, 2, 2)
    assert simulate_batch(gm, []) == []


def test_select_latency_zero_rejected():
    # latency 0 would make the sel_wait countdown start at -1 and deadlock
    with pytest.raises(ValueError, match="select_latency"):
        OverlayConfig(select_latency=0)


def test_scan_latency_exposed():
    # scan's exposed pick cost defaults to the RDY word count and is
    # configurable; a deeper exposed scan must cost cycles.
    g = wl.reduction_tree(64)
    fast = _run(g, 2, 2, "scan", select_latency=1)
    slow = _run(g, 2, 2, "scan", select_latency=8)
    assert fast.done and slow.done
    assert slow.cycles > fast.cycles


def test_sharded_runs_all_policies():
    # 1x1 mesh exercises the shard_map code path on any backend.
    import jax

    from repro.core.distributed import simulate_sharded

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = wl.arrow_lu_graph(2, 5, 3, seed=4)
    ref = reference_evaluate(g)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    for sched in ALL_POLICIES:
        cfg = OverlayConfig(scheduler=sched, max_cycles=500_000)
        r1 = simulate(gm, cfg)
        r2 = simulate_sharded(gm, mesh, cfg)
        assert r2.done, sched
        assert r1.cycles == r2.cycles, sched
        np.testing.assert_array_equal(r2.values, ref)
