"""Chunked cross-entropy: the [B, T, vocab] logits tensor never materializes.

With vocab up to 256K (gemma) and 1M tokens per train step, full logits would
be ~0.5 TB in bf16 — instead the head matmul + log-softmax run per sequence
chunk under ``jax.checkpoint``, so peak live memory is one chunk's logits and
backward recomputes them. This is a standard large-vocab production trick and
part of the beyond-paper §Perf story.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_softmax_xent(h, head, labels, mask=None, chunk: int = 2048,
                         z_loss: float = 0.0, valid_vocab: int | None = None):
    """h: [b, t, d]; head: [d, V]; labels: [b, t] int32; mask: [b, t] (1=count).

    ``valid_vocab``: mask logit columns >= this (padded embedding tables).
    Returns (mean_loss, metrics). Loss in f32.
    """
    b, t, d = h.shape
    v = head.shape[-1]
    c = min(chunk, t)
    pad = -t % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else None
    tp = t + pad
    nc = tp // c
    if mask is None:
        mask = (jnp.arange(tp)[None, :] < t).astype(jnp.float32) * jnp.ones((b, 1))
    mask = mask.astype(jnp.float32)

    hs = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, c).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_fn(carry, xs):
        hc, lc, mc = xs
        logits = (hc.astype(jnp.float32) @ head.astype(jnp.float32))      # [b,c,V]
        if valid_vocab is not None and valid_vocab < v:
            logits = jnp.where(jnp.arange(v) < valid_vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)                            # [b,c]
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        zl = (lse ** 2) * mc * z_loss
        correct = (logits.argmax(-1) == lc).astype(jnp.float32) * mc
        loss_sum, z_sum, denom, ncorrect = carry
        return (loss_sum + nll.sum(), z_sum + zl.sum(), denom + mc.sum(),
                ncorrect + correct.sum()), None

    (loss_sum, z_sum, denom, ncorrect), _ = jax.lax.scan(
        chunk_fn, (jnp.float32(0), jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        (hs, ls, ms))
    denom = jnp.maximum(denom, 1.0)
    loss = loss_sum / denom + z_sum / denom
    return loss, {"xent": loss_sum / denom, "accuracy": ncorrect / denom,
                  "tokens": denom}
