"""Train / serve step builders.

``make_train_step`` returns a pure function (state, batch) -> (state, metrics)
suitable for jit/pjit with donated state; gradient accumulation is a
``lax.scan`` over microbatches with f32 gradient accumulators (comm overlap:
the per-microbatch backward and the accumulator adds pipeline under XLA's
scheduler; the single optimizer apply keeps FSDP reduce traffic at 1x).

Serve steps: prefill fills the KV/SSM cache from a prompt; decode_step
advances one token (greedy or sampled).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig
from .losses import chunked_softmax_xent


def make_loss_fn(cfg: ModelConfig, moe_aux_coef: float = 0.01, z_loss: float = 0.0):
    def loss_fn(params, batch):
        if cfg.encdec is not None:
            h, metrics = lm.forward_encdec(params, cfg, batch["frames"], batch["tokens"])
        elif "embeds" in batch:
            h, metrics = lm.forward(params, cfg, embeds=batch["embeds"])
        else:
            h, metrics = lm.forward(params, cfg, tokens=batch["tokens"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        loss, lmetrics = chunked_softmax_xent(
            h, head, batch["labels"], batch.get("mask"), chunk=cfg.loss_chunk,
            z_loss=z_loss, valid_vocab=cfg.vocab_size)
        total = loss
        if metrics:
            total = total + moe_aux_coef * metrics.get("moe_aux", 0.0)
        return total, {**lmetrics, **{k: v for k, v in metrics.items()}}

    return loss_fn


def init_train_state(key, cfg: ModelConfig, optimizer) -> dict:
    params = lm.init(key, cfg)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, optimizer, *, grad_accum: int = 1,
                    moe_aux_coef: float = 0.01, z_loss: float = 0.0):
    loss_fn = make_loss_fn(cfg, moe_aux_coef, z_loss)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = grad_fn(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = {}
        new_params, new_opt, om = optimizer.update(grads, state["opt"], params)
        out_metrics = {"loss": loss, **metrics, **om}
        return ({"params": new_params, "opt": new_opt, "step": state["step"] + 1},
                out_metrics)

    return train_step


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        if cfg.encdec is not None:
            return lm.prefill_encdec(params, cfg, batch["frames"], batch["tokens"], cache)
        if "embeds" in batch:
            return lm.prefill(params, cfg, embeds=batch["embeds"], cache=cache)
        return lm.prefill(params, cfg, tokens=batch["tokens"], cache=cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, greedy: bool = True, temperature: float = 1.0):
    def decode_step(params, tokens, cache, cache_len, key=None):
        if cfg.encdec is not None:
            logits, cache = lm.decode_step_encdec(params, cfg, tokens, cache, cache_len)
        else:
            logits, cache = lm.decode_step(params, cfg, tokens, cache, cache_len)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return decode_step
