"""NoC-aware placement cost model, vmapped over candidate placements.

A candidate placement is a ``[N]`` node -> PE assignment. Its cost has two
terms, both *integer-valued* so every score (and every annealer accept
decision built on score deltas) is bit-deterministic across machines and XLA
versions:

  * **traffic** — hop-weighted NoC load: each dataflow edge pays the
    dimension-ordered hop count of the unidirectional Hoplite torus between
    its endpoint PEs (``(dx mod nx) + (dy mod ny)`` — the torus is one-way,
    so going "back" one column costs ``nx - 1`` hops, exactly like the
    simulator), weighted ``1 + crit_scale * crit / crit_max`` so edges on the
    critical chain count more (they are latency-, not just bandwidth-bound).
  * **slot pressure** — criticality-weighted load balance: each PE's load is
    the sum of its nodes' integer weights (same criticality ramp), and the
    term is the sum of squared loads. Quadratic pressure penalizes piling
    work — especially critical work — onto few PEs, which both serializes
    fire opportunities (1 fire/PE/cycle) and deepens local memories.

``total = traffic + pressure_weight * pressure``. The model is a pure jnp
function of the placement vector, so thousands of candidates score as one
``jax.vmap`` batch on-device (:meth:`CostModel.batch_cost`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.criticality import criticality as _criticality
from ..core.graph import DataflowGraph


def edge_endpoints(g: DataflowGraph) -> tuple[np.ndarray, np.ndarray]:
    """CSR fanout lists -> flat ([E] src, [E] dst) int32 endpoint arrays."""
    src = np.repeat(np.arange(g.num_nodes, dtype=np.int32), g.fanout_count())
    return src, g.fanout_dst.astype(np.int32)


def integer_weights(crit: np.ndarray, crit_scale: int) -> np.ndarray:
    """[N] int32 weights ``1 + crit_scale * crit / crit_max`` (floored)."""
    c = np.asarray(crit, dtype=np.int64)
    c = c - c.min() if c.size else c  # neg_slack labels are <= 0
    top = max(1, int(c.max(initial=0)))
    return (1 + (crit_scale * c) // top).astype(np.int32)


def edge_tables(
    g: DataflowGraph, *, metric: str = "height", crit_scale: int = 3,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flat integer scoring tables ``(src, dst, w_edge, w_node)`` for ``g``.

    ``w_node`` is the criticality ramp :func:`integer_weights`; each edge
    carries its *source* node's weight (the token that travels is the source's
    result). This is the shared table builder behind :func:`build_cost_model`,
    the surrogate feature extractor (:mod:`repro.surrogate.features`) and the
    multilevel coarsener (:mod:`repro.place.coarsen`) — one definition of
    "edge weight" keeps their notions of criticality aligned.
    """
    crit = _criticality(g, metric)
    src, dst = edge_endpoints(g)
    w_node = integer_weights(crit, crit_scale)
    return src, dst, w_node[src].astype(np.int32), w_node


def torus_hops(src_pe, dst_pe, nx: int, ny: int):
    """Dimension-ordered hop count on the unidirectional nx x ny torus.

    PE ids follow the overlay convention ``pe = x * ny + y``.
    """
    sx, sy = src_pe // ny, src_pe % ny
    dx_, dy_ = dst_pe // ny, dst_pe % ny
    return jnp.mod(dx_ - sx, nx) + jnp.mod(dy_ - sy, ny)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Static per-graph scoring tables + the jnp cost functions."""

    nx: int
    ny: int
    src: jnp.ndarray          # [E] int32 edge source node
    dst: jnp.ndarray          # [E] int32 edge destination node
    w_edge: jnp.ndarray       # [E] int32 criticality edge weight
    w_node: jnp.ndarray       # [N] int32 criticality node weight
    pressure_weight: int

    @property
    def num_pes(self) -> int:
        return self.nx * self.ny

    def traffic(self, node_pe) -> jnp.ndarray:
        with enable_x64():  # int64 accumulations must not wrap (see cost())
            node_pe = jnp.asarray(node_pe, jnp.int32)
            hops = torus_hops(node_pe[self.src], node_pe[self.dst],
                              self.nx, self.ny)
            return jnp.sum(self.w_edge.astype(jnp.int64)
                           * hops.astype(jnp.int64))

    def loads(self, node_pe) -> jnp.ndarray:
        """[P] int64 criticality-weighted node load per PE."""
        with enable_x64():
            return jnp.zeros(self.num_pes, jnp.int64).at[
                jnp.asarray(node_pe, jnp.int32)].add(
                    self.w_node.astype(jnp.int64))

    def pressure(self, node_pe) -> jnp.ndarray:
        with enable_x64():
            loads = self.loads(node_pe)
            return jnp.sum(loads * loads)

    def cost(self, node_pe) -> jnp.ndarray:
        """Scalar int64 cost of one [N] placement (jit-able).

        Runs under scoped x64 so the squared-load accumulation cannot wrap
        on large graphs (callers need no global ``jax_enable_x64``)."""
        with enable_x64():
            node_pe = jnp.asarray(node_pe, jnp.int32)
            return (self.traffic(node_pe)
                    + self.pressure_weight * self.pressure(node_pe))

    def batch_cost(self, placements) -> jnp.ndarray:
        """[B] int64 costs of a stacked [B, N] candidate batch (one vmap)."""
        with enable_x64():
            return jax.vmap(self.cost)(jnp.asarray(placements, jnp.int32))


def build_cost_model(
    g: DataflowGraph,
    nx: int,
    ny: int,
    *,
    metric: str = "height",
    crit_scale: int = 3,
    pressure_weight: int = 1,
) -> CostModel:
    """Precompute the scoring tables for ``g`` on an ``nx x ny`` grid."""
    src, dst, w_edge, w_node = edge_tables(
        g, metric=metric, crit_scale=crit_scale)
    return CostModel(
        nx=nx, ny=ny,
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        w_edge=jnp.asarray(w_edge),   # edge carries its source's weight
        w_node=jnp.asarray(w_node),
        pressure_weight=int(pressure_weight),
    )
