"""Fast placer smoke: ``python -m repro.place [--smoke]``.

Runs the full subsystem end to end on a small fig1-family workload in a few
seconds and asserts its contracts:

  * identity placement is bit-identical to the legacy direct-GraphMemory
    path (the guarantee the committed benchmark cycle counts rest on);
  * the annealer is deterministic for a fixed key and never scores worse
    than its random init;
  * the annealed placement's simulated cycle count beats the random one.

CI runs this as a cheap gate next to the tier-1 tests.
"""
from __future__ import annotations

import sys


def main() -> int:
    import numpy as np

    from repro.core import workloads as wl
    from repro.api import run
    from repro.core.overlay import OverlayConfig
    from repro.core.partition import build_graph_memory
    from repro import place

    g = wl.arrow_lu_graph(2, 8, 6, seed=3)
    nx = ny = 8
    acfg = place.AnnealConfig(replicas=6, rounds=12, steps=256, seed=0)

    # 1. identity == legacy path, bit-exact.
    legacy = run(build_graph_memory(g, nx, ny),
                      OverlayConfig(max_cycles=200_000))
    via_place = run(g, OverlayConfig(max_cycles=200_000), nx=nx, ny=ny)
    assert via_place.cycles == legacy.cycles, (via_place.cycles, legacy.cycles)
    np.testing.assert_array_equal(via_place.values, legacy.values)

    # 2. determinism + cost monotonicity vs the random init.
    r1 = place.anneal_placement(g, nx, ny, acfg)
    r2 = place.anneal_placement(g, nx, ny, acfg)
    np.testing.assert_array_equal(r1.node_pe, r2.node_pe)
    assert r1.cost <= r1.init_cost, (r1.cost, r1.init_cost)

    # 3. annealed beats random on simulated cycles.
    spec_rand = place.PlacementSpec(strategy="random", seed=acfg.seed)
    res = place.evaluate_placements(
        g, nx, ny,
        {"random": spec_rand, "annealed": r1.node_pe},
        cfgs=OverlayConfig(max_cycles=400_000))
    rand, ann = res["random"], res["annealed"]
    assert rand.done and ann.done
    assert ann.cycles < rand.cycles, (ann.cycles, rand.cycles)

    print(f"place smoke OK: identity={legacy.cycles} cycles, "
          f"anneal cost {r1.init_cost} -> {r1.cost} "
          f"({100 * r1.improvement:.1f}%), "
          f"cycles random={rand.cycles} annealed={ann.cycles}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
