"""Batched parallel-tempering placer: the whole search is one XLA program.

Search shape
------------
``replicas`` candidate placements evolve side by side (one ``jax.vmap`` over
the replica axis). Each replica runs *threshold accepting* — the
deterministic simulated-annealing variant: a proposed single-node move is
accepted iff its integer cost delta is ``<= threshold[r]`` — with thresholds
laddered geometrically from ``t_max`` (hot, explores) down to 0 (cold, pure
greedy). Every round (``steps`` proposals per replica under ``lax.scan``) a
parallel-tempering exchange runs across adjacent ladder rungs: the lower-cost
configuration migrates toward the cold end (the deterministic limit of the
classic Metropolis swap rule), so discoveries made while hot get polished
greedily without restarts.

Determinism
-----------
Costs, deltas, and accept decisions are all int64 arithmetic on int32 tables
(:mod:`repro.place.cost`), and proposals come from the counter-based JAX
PRNG, so for a fixed :class:`repro.place.spec.AnnealConfig` the result is
bit-identical across runs, machines, and backends. That is what lets
``BENCH_overlay.json`` gate *cycle counts of annealed placements* in CI.

Move evaluation is O(degree), not O(E): moving node ``v`` only re-prices the
edges incident to ``v`` (gathered from a padded host-built incidence table)
plus a two-PE load update — the carried per-PE load vector makes the
quadratic pressure delta ``2 w (load[q] - load[p] + w)``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.graph import DataflowGraph
from .cost import CostModel, build_cost_model, edge_endpoints, torus_hops
from .spec import AnnealConfig


@dataclasses.dataclass(frozen=True)
class PlacementResult:
    """Best placement found plus search diagnostics."""

    node_pe: np.ndarray        # [N] int32 node -> PE
    cost: int                  # integer model cost of node_pe
    init_cost: int             # cost of the initial placement
    replica_costs: np.ndarray  # [R] per-replica best costs (ladder health)

    @property
    def improvement(self) -> float:
        return 1.0 - self.cost / max(1, self.init_cost)


@dataclasses.dataclass(frozen=True)
class GuidedPlacementResult(PlacementResult):
    """:class:`PlacementResult` of a surrogate-guided search.

    ``cost_evals`` counts the proposals that passed the surrogate gate and
    therefore reached the integer cost/accept rule (ungated proposals under
    ``guide_every > 1`` count too); ``proposals`` is the total budget
    ``replicas * rounds * steps`` — what an *unguided* run of the same
    config would have cost-evaluated. Both are exact deterministic integers,
    so the BENCH ``guided`` section CI-gates the ratio.

    The counter is an *accounting* metric — the proxy-in-the-loop claim for
    systems where evaluating the true cost dominates. Inside this
    branchless jitted kernel every delta is still computed, and each
    proposal additionally pays the O(degree)+O(P) surrogate update, so
    guided wall-clock per proposal is higher, not lower.
    """

    cost_evals: int = 0
    proposals: int = 0

    @property
    def eval_ratio(self) -> float:
        return self.cost_evals / max(1, self.proposals)


def incidence_table(g: DataflowGraph, w_edge: np.ndarray):
    """Padded per-node incident-edge table for O(degree) move deltas.

    Returns ([N, D] neighbor node, [N, D] int32 edge weight — 0 marks
    padding, [N, D] bool "node is the edge source"). D = max total degree
    (fanin <= 2, fanout unbounded).
    """
    src, dst = edge_endpoints(g)
    return incidence_from_edges(src, dst, w_edge, g.num_nodes)


def incidence_layout(src: np.ndarray, dst: np.ndarray, n: int):
    """Shared incidence layout: each edge appears once per endpoint.

    Returns ``(owner, pos, order, d_max)`` — the owning node of each
    (sorted) entry, its position within the owner's row, the sort
    permutation over the doubled ``[src; dst]`` edge list, and the padded
    row width. Both the weight tables (:func:`incidence_from_edges`) and
    arbitrary per-edge payloads (:func:`incidence_payload`) scatter through
    this one layout, so their entries line up index-for-index.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    owner = np.concatenate([src, dst])
    order = np.argsort(owner, kind="stable")
    owner = owner[order]
    m = owner.shape[0]
    # Position of each entry within its owner's group (same trick as the
    # slot assigner): running index minus the group's start index.
    starts = np.zeros(m, dtype=np.int64)
    if m:
        group_start = np.r_[0, np.flatnonzero(np.diff(owner)) + 1]
        starts[group_start] = group_start
        starts = np.maximum.accumulate(starts)
    pos = np.arange(m) - starts
    d_max = max(1, int(pos.max(initial=0)) + 1)
    return owner, pos, order, d_max


def incidence_from_edges(src: np.ndarray, dst: np.ndarray,
                         w_edge: np.ndarray, n: int, *, layout=None):
    """:func:`incidence_table` over flat ``(src, dst)`` edge arrays.

    The annealer itself only needs incident-edge tables, not a
    :class:`DataflowGraph` — this is the entry point the multilevel
    coarsener (:mod:`repro.place.coarsen`) uses to anneal *cluster*-level
    quotient graphs with the very same jitted search kernel.
    """
    w_edge = np.asarray(w_edge, dtype=np.int32)
    other = np.concatenate([np.asarray(dst, np.int64),
                            np.asarray(src, np.int64)]).astype(np.int32)
    w = np.concatenate([w_edge, w_edge])
    out = np.concatenate([np.ones(len(w_edge), bool),
                          np.zeros(len(w_edge), bool)])

    owner, pos, order, d_max = layout or incidence_layout(src, dst, n)
    nbr = np.zeros((n, d_max), dtype=np.int32)
    w_pad = np.zeros((n, d_max), dtype=np.int32)
    is_out = np.zeros((n, d_max), dtype=bool)
    nbr[owner, pos] = other[order]
    w_pad[owner, pos] = w[order]
    is_out[owner, pos] = out[order]
    return nbr, w_pad, is_out


def incidence_payload(src: np.ndarray, dst: np.ndarray,
                      values: np.ndarray, n: int, *, layout=None) -> np.ndarray:
    """[N, D] per-incident-edge payload table in the exact layout of
    :func:`incidence_from_edges` (0 marks padding) — the guided annealer
    uses it to ride critical-edge / multiplicity tables alongside the
    weights."""
    values = np.asarray(values)
    owner, pos, order, d_max = layout or incidence_layout(src, dst, n)
    out = np.zeros((n, d_max), dtype=values.dtype)
    out[owner, pos] = np.concatenate([values, values])[order]
    return out


def _pe_loads(pe, w_node, num_pes: int):
    """[P] int64 criticality-weighted item load per PE."""
    return jnp.zeros(num_pes, jnp.int64).at[pe].add(w_node.astype(jnp.int64))


def _placement_cost(pe, nbr, w_inc, is_out, w_node, pw, nx: int, ny: int):
    """Full integer model cost of one [N] placement (traffic + pressure).

    Each incidence entry appears once per endpoint; out-edges only, so
    every edge is counted exactly once.
    """
    n = pe.shape[0]
    nbr_pe = pe[jnp.clip(nbr, 0, n - 1)]
    hop = torus_hops(pe[:, None], nbr_pe, nx, ny)
    traffic = jnp.sum(jnp.where(is_out, w_inc, 0).astype(jnp.int64)
                      * hop.astype(jnp.int64))
    loads = _pe_loads(pe, w_node, nx * ny)
    return traffic + pw * jnp.sum(loads * loads)


def _move_delta(pe, load, i, q, nbr, w_inc, is_out, w_node, pw,
                nx: int, ny: int):
    """O(degree) integer cost delta of moving item ``i`` to PE ``q``.

    Returns ``(delta, p, wn)`` — the delta, the item's current PE, and its
    int64 weight (what the accept commit needs). Shared by the plain and
    guided kernels so their objectives cannot drift apart; both pinned
    bit-exact by the open-gate equivalence test.
    """
    p = pe[i]
    nb, wv, out = nbr[i], w_inc[i], is_out[i]
    nbr_pe = pe[nb]
    old_h = jnp.where(out, torus_hops(p, nbr_pe, nx, ny),
                      torus_hops(nbr_pe, p, nx, ny))
    new_h = jnp.where(out, torus_hops(q, nbr_pe, nx, ny),
                      torus_hops(nbr_pe, q, nx, ny))
    d_traffic = jnp.sum(wv.astype(jnp.int64)
                        * (new_h - old_h).astype(jnp.int64))
    wn = w_node[i].astype(jnp.int64)
    d_pressure = 2 * wn * (load[q] - load[p] + wn)
    return d_traffic + pw * d_pressure, p, wn


def _pt_take(costs, parity):
    """[R] replica-permutation indices of one parallel-tempering exchange:
    the lower-cost configuration of each adjacent ladder pair migrates
    toward the cold (low-r) end. Shared by the plain and guided kernels so
    their swap rules cannot drift apart."""
    r = jnp.arange(costs.shape[0])
    off = r - parity
    partner = jnp.where(off < 0, r,
                        jnp.where(off % 2 == 0, r + 1, r - 1))
    partner = jnp.clip(partner, 0, costs.shape[0] - 1)
    lo = jnp.minimum(r, partner)
    hi = jnp.maximum(r, partner)
    swap = (partner != r) & (costs[hi] < costs[lo])
    return jnp.where(swap, partner, r)


def _thresholds(acfg: AnnealConfig) -> np.ndarray:
    """[R] int64 acceptance thresholds: 0 (greedy) then geometric to t_max."""
    r = acfg.replicas
    t = float(acfg.t_max)
    if r == 1 or t <= 0:
        return np.zeros(r, dtype=np.int64)
    if r == 2:
        ladder = np.array([t])          # single hot rung sits AT t_max
    else:
        ladder = np.geomspace(min(2.0, t), t, r - 1)
    return np.concatenate([[0], np.rint(ladder).astype(np.int64)])


@functools.partial(jax.jit, static_argnames=("nx", "ny", "rounds", "steps",
                                             "pressure_weight"))
def _anneal_jit(init_pe, nbr, w_inc, is_out, w_node, thresholds, key,
                *, nx: int, ny: int, rounds: int, steps: int,
                pressure_weight: int):
    R = thresholds.shape[0]
    N = init_pe.shape[0]
    P = nx * ny
    pw = jnp.int64(pressure_weight)

    def loads_of(pe):
        return _pe_loads(pe, w_node, P)

    def full_cost(pe):
        return _placement_cost(pe, nbr, w_inc, is_out, w_node, pw, nx, ny)

    def propose(st, key, thresh):
        pe, load, cost = st
        k1, k2 = jax.random.split(key)
        # int32 dtype pinned: the drawn sequence must not depend on the
        # ambient x64 mode (bit-determinism contract).
        i = jax.random.randint(k1, (), 0, N, dtype=jnp.int32)
        q = jax.random.randint(k2, (), 0, P, dtype=jnp.int32)
        delta, p, wn = _move_delta(pe, load, i, q, nbr, w_inc, is_out,
                                   w_node, pw, nx, ny)
        accept = (delta <= thresh) & (p != q)
        pe = pe.at[i].set(jnp.where(accept, q, p))
        load = load.at[p].add(jnp.where(accept, -wn, 0))
        load = load.at[q].add(jnp.where(accept, wn, 0))
        return (pe, load, cost + jnp.where(accept, delta, jnp.int64(0)))

    def sweep(st_keys, _):
        st, keys = st_keys
        new_keys = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        step_keys, keys = new_keys[:, 0], new_keys[:, 1]
        st = jax.vmap(propose)(st, step_keys, thresholds)
        return (st, keys), None

    def pt_swap(st, costs, parity):
        take = _pt_take(costs, parity)
        return jax.tree.map(lambda a: a[take], st), costs[take]

    def round_body(carry, parity):
        st, keys, best_pe, best_cost = carry
        (st, keys), _ = jax.lax.scan(sweep, (st, keys), None, length=steps)
        pe, load, cost = st
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_pe = jnp.where(better[:, None], pe, best_pe)
        pe, cost = pt_swap(pe, cost, parity)
        load = jax.vmap(loads_of)(pe)
        return ((pe, load, cost), keys, best_pe, best_cost), None

    pe0 = jnp.broadcast_to(init_pe, (R, N)).astype(jnp.int32)
    load0 = jax.vmap(loads_of)(pe0)
    cost0 = jax.vmap(full_cost)(pe0)
    keys = jax.random.split(key, R)
    carry = ((pe0, load0, cost0), keys, pe0, cost0)
    parities = jnp.arange(rounds, dtype=jnp.int32) % 2
    (_, _, best_pe, best_cost), _ = jax.lax.scan(round_body, carry, parities)
    return best_pe, best_cost, cost0[0]


@functools.partial(jax.jit, static_argnames=("nx", "ny", "rounds", "steps",
                                             "pressure_weight", "guide_every"))
def _anneal_guided_jit(init_pe, nbr, w_inc, is_out, w_node, thresholds, key,
                       ga, q_margin, *, nx: int, ny: int, rounds: int,
                       steps: int, pressure_weight: int, guide_every: int):
    """Two-stage-accept variant of :func:`_anneal_jit`.

    Every proposal is first scored by the integer-quantized surrogate via an
    O(degree) incremental feature delta (:mod:`repro.surrogate.delta`); only
    proposals the surrogate rates promising (``dscore <= q_margin``, on
    steps selected by ``guide_every``) proceed to the usual integer
    cost/accept rule. The PRNG stream, cost arithmetic, best tracking, and
    PT exchange are identical to the unguided kernel, so with the gate wide
    open (``q_margin = int64 max``) the trajectory reproduces
    :func:`_anneal_jit` bit-for-bit (pinned in ``tests/test_guided.py``).
    """
    from ..surrogate.delta import apply_move, state_init

    R = thresholds.shape[0]
    N = init_pe.shape[0]
    P = nx * ny
    pw = jnp.int64(pressure_weight)

    def loads_of(pe):
        return _pe_loads(pe, w_node, P)

    def full_cost(pe):
        return _placement_cost(pe, nbr, w_inc, is_out, w_node, pw, nx, ny)

    def propose(st, key, thresh, j):
        pe, load, cost, gst, evals = st
        k1, k2 = jax.random.split(key)
        i = jax.random.randint(k1, (), 0, N, dtype=jnp.int32)
        q = jax.random.randint(k2, (), 0, P, dtype=jnp.int32)

        # Stage 1 — surrogate gate: exact incremental features, quantized
        # predicted-cycle delta. Gate-rejected proposals are dead on
        # arrival: the cost rule cannot accept them, and they don't count
        # as full-cost evaluations. (The branchless jitted kernel still
        # *computes* every delta — the counter is the accounting metric for
        # systems where the true cost evaluation is the scarce resource,
        # not a wall-clock claim about this kernel.)
        gst_new, dscore = apply_move(ga, gst, pe, i, q, nx=nx, ny=ny)
        gated = (j % guide_every) == 0
        promising = jnp.where(gated, dscore <= q_margin, True)

        # Stage 2 — the unguided kernel's integer cost/threshold accept.
        delta, p, wn = _move_delta(pe, load, i, q, nbr, w_inc, is_out,
                                   w_node, pw, nx, ny)
        accept = promising & (delta <= thresh) & (p != q)
        pe = pe.at[i].set(jnp.where(accept, q, p))
        load = load.at[p].add(jnp.where(accept, -wn, 0))
        load = load.at[q].add(jnp.where(accept, wn, 0))
        cost = cost + jnp.where(accept, delta, jnp.int64(0))
        gst = jax.tree.map(lambda a, b: jnp.where(accept, a, b), gst_new, gst)
        evals = evals + promising.astype(jnp.int64)
        return (pe, load, cost, gst, evals)

    def sweep(st_keys, j):
        st, keys = st_keys
        new_keys = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        step_keys, keys = new_keys[:, 0], new_keys[:, 1]
        st = jax.vmap(propose, in_axes=(0, 0, 0, None))(
            st, step_keys, thresholds, j)
        return (st, keys), None

    def round_body(carry, parity):
        st, keys, best_pe, best_cost = carry
        (st, keys), _ = jax.lax.scan(sweep, (st, keys),
                                     jnp.arange(steps, dtype=jnp.int32))
        pe, load, cost, gst, evals = st
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_pe = jnp.where(better[:, None], pe, best_pe)
        take = _pt_take(cost, parity)
        pe, cost = pe[take], cost[take]
        gst = jax.tree.map(lambda a: a[take], gst)
        load = jax.vmap(loads_of)(pe)
        # evals stays un-permuted: the counters belong to the ladder rungs,
        # not the migrating configurations (their sum is invariant anyway).
        return ((pe, load, cost, gst, evals), keys, best_pe, best_cost), None

    pe0 = jnp.broadcast_to(init_pe, (R, N)).astype(jnp.int32)
    load0 = jax.vmap(loads_of)(pe0)
    cost0 = jax.vmap(full_cost)(pe0)
    gst0 = jax.vmap(lambda pe: state_init(ga, pe, nx=nx, ny=ny))(pe0)
    evals0 = jnp.zeros(R, jnp.int64)
    keys = jax.random.split(key, R)
    carry = ((pe0, load0, cost0, gst0, evals0), keys, pe0, cost0)
    parities = jnp.arange(rounds, dtype=jnp.int32) % 2
    (st, _, best_pe, best_cost), _ = jax.lax.scan(round_body, carry, parities)
    return best_pe, best_cost, cost0[0], st[4]


def anneal_tables(
    n: int,
    nx: int,
    ny: int,
    src: np.ndarray,
    dst: np.ndarray,
    w_edge: np.ndarray,
    w_node: np.ndarray,
    acfg: AnnealConfig | None = None,
    *,
    init: np.ndarray | None = None,
    guide=None,
    guide_every: int = 1,
    guide_margin: float = 0.0,
) -> PlacementResult:
    """Anneal an ``[n]`` item -> PE placement from flat integer edge tables.

    ``n`` items (graph nodes — or node *clusters* in the multilevel pipeline)
    connected by ``(src, dst)`` edges of weight ``w_edge``, with per-item
    weights ``w_node``, are placed on the ``nx x ny`` torus. This is the
    graph-free core of :func:`anneal_placement`: same jitted kernel, same
    determinism contract, no :class:`DataflowGraph` needed.

    ``guide`` switches on the two-stage surrogate accept: a
    :class:`repro.surrogate.delta.Guide` (or a fitted
    :class:`~repro.surrogate.model.SurrogateModel`, converted on the spot)
    built for the *same* ``n`` items on the same grid — its extractor may
    weight edges its own way, but it must describe this item set. Proposals
    whose quantized predicted-cycle delta exceeds ``guide_margin`` (in
    predicted cycles; ``inf`` disables the gate) are rejected before the
    integer cost rule; ``guide_every=k`` applies the gate on every k-th
    proposal of a sweep only. Guided searches return a
    :class:`GuidedPlacementResult` carrying the exact cost-evaluation count.
    """
    acfg = acfg or AnnealConfig()
    num_pes = nx * ny
    if init is None:
        rng = np.random.default_rng(acfg.seed)
        init = rng.integers(0, num_pes, size=n).astype(np.int32)
    init = np.asarray(init, dtype=np.int32)
    if init.shape != (n,):
        raise ValueError(f"init must be [{n}] item->PE, got {init.shape}")
    if init.size and (init.min() < 0 or init.max() >= num_pes):
        raise ValueError("init placement references PEs outside the grid")

    nbr, w_inc, is_out = incidence_from_edges(src, dst, w_edge, n)
    # Host numpy throughout: the arrays cross into jax at the jit boundary,
    # inside the scoped x64 below — an eager jnp.asarray here would silently
    # truncate the int64 thresholds to int32 when ambient x64 is off.
    args = (init, nbr, w_inc, is_out, np.asarray(w_node, np.int32),
            _thresholds(acfg), jax.random.key(acfg.seed))
    knobs = dict(nx=nx, ny=ny, rounds=acfg.rounds, steps=acfg.steps,
                 pressure_weight=acfg.pressure_weight)
    # Scoped x64: cost totals are int64 sums of squared loads — they must not
    # wrap on big graphs, and callers shouldn't need global jax_enable_x64.
    if guide is None:
        with enable_x64():
            best_pe, best_cost, init_cost = _anneal_jit(*args, **knobs)
        evals = None
    else:
        from ..surrogate.delta import (Guide, build_guide, guide_arrays,
                                       quantize_margin)

        if not isinstance(guide, Guide):
            guide = build_guide(guide)
        ex = guide.extractor
        if ex.num_items != n or (ex.nx, ex.ny) != (nx, ny):
            raise ValueError(
                f"guide was built for {ex.num_items} items on a "
                f"{ex.nx}x{ex.ny} grid; this search places {n} items on "
                f"{nx}x{ny}")
        if guide_every < 1:
            raise ValueError(f"guide_every must be >= 1, got {guide_every}")
        with enable_x64():
            best_pe, best_cost, init_cost, evals = _anneal_guided_jit(
                *args, guide_arrays(guide),
                np.int64(quantize_margin(guide_margin)),
                guide_every=int(guide_every), **knobs)
    best_pe = np.asarray(best_pe)
    best_cost = np.asarray(best_cost)
    b = int(best_cost.argmin())
    fields = dict(
        node_pe=best_pe[b].astype(np.int32),
        cost=int(best_cost[b]),
        init_cost=int(init_cost),
        replica_costs=best_cost.astype(np.int64),
    )
    if guide is None:
        return PlacementResult(**fields)
    return GuidedPlacementResult(
        **fields, cost_evals=int(np.asarray(evals).sum()),
        proposals=acfg.replicas * acfg.rounds * acfg.steps)


def anneal_tables_many(
    n: int,
    nx: int,
    ny: int,
    src: np.ndarray,
    dst: np.ndarray,
    w_edge: np.ndarray,
    w_node: np.ndarray,
    acfgs,
    *,
    inits=None,
) -> list[PlacementResult]:
    """Run MANY independent anneals of one item set as a single XLA program.

    The service batch executor's fan-out: ``Q`` queries that share the graph
    tables and grid (typically differing in ``seed`` / ``t_max``) vmap over
    the query axis of the same jitted kernel — one compile, one dispatch,
    ``Q x replicas`` ladders in flight. Every element is bit-identical to a
    solo :func:`anneal_tables` call with the same config (integer cost
    arithmetic and the counter-based PRNG are exact under vmap; asserted in
    ``tests/test_service.py``).

    Static kernel knobs must be uniform across ``acfgs``: ``replicas``,
    ``rounds``, ``steps``, ``pressure_weight`` (they shape the program).
    Per-query values may vary: ``seed`` (init + proposal stream) and
    ``t_max`` (thresholds ride in as data). Guided anneals don't batch —
    resolve those queries solo.
    """
    acfgs = list(acfgs)
    if not acfgs:
        return []
    statics = {(a.replicas, a.rounds, a.steps, a.pressure_weight)
               for a in acfgs}
    if len(statics) != 1:
        raise ValueError(
            f"anneal_tables_many needs uniform (replicas, rounds, steps, "
            f"pressure_weight) across the batch — they shape the compiled "
            f"kernel; got {sorted(statics)}. Group queries by these knobs.")
    num_pes = nx * ny
    if inits is None:
        inits = [None] * len(acfgs)
    init_rows = []
    for a, init in zip(acfgs, inits):
        if init is None:
            rng = np.random.default_rng(a.seed)
            init = rng.integers(0, num_pes, size=n).astype(np.int32)
        init = np.asarray(init, dtype=np.int32)
        if init.shape != (n,):
            raise ValueError(f"init must be [{n}] item->PE, got {init.shape}")
        if init.size and (init.min() < 0 or init.max() >= num_pes):
            raise ValueError("init placement references PEs outside the grid")
        init_rows.append(init)

    nbr, w_inc, is_out = incidence_from_edges(src, dst, w_edge, n)
    init_pes = np.stack(init_rows)
    thresholds = np.stack([_thresholds(a) for a in acfgs])
    keys = jnp.stack([jax.random.key(a.seed) for a in acfgs])
    run1 = functools.partial(
        _anneal_jit, nx=nx, ny=ny, rounds=acfgs[0].rounds,
        steps=acfgs[0].steps, pressure_weight=acfgs[0].pressure_weight)
    w_node = np.asarray(w_node, np.int32)
    with enable_x64():
        best_pe, best_cost, init_cost = jax.vmap(
            run1, in_axes=(0, None, None, None, None, 0, 0))(
                init_pes, nbr, w_inc, is_out, w_node, thresholds, keys)
    best_pe = np.asarray(best_pe)
    best_cost = np.asarray(best_cost)
    init_cost = np.asarray(init_cost)
    out = []
    for q in range(len(acfgs)):
        b = int(best_cost[q].argmin())
        out.append(PlacementResult(
            node_pe=best_pe[q, b].astype(np.int32),
            cost=int(best_cost[q, b]),
            init_cost=int(init_cost[q]),
            replica_costs=best_cost[q].astype(np.int64)))
    return out


def anneal_placements(
    g: DataflowGraph,
    nx: int,
    ny: int,
    acfgs,
    *,
    metric: str = "height",
    inits=None,
    model: CostModel | None = None,
) -> list[PlacementResult]:
    """Many independent :func:`anneal_placement` searches, one XLA program.

    All queries share one cost model (so ``metric`` and the configs'
    ``crit_scale`` must be uniform — the weight tables are data to the
    vmapped kernel, but a per-query metric would mean per-query tables and
    defeat the sharing). See :func:`anneal_tables_many` for the uniformity
    contract and the bit-exactness guarantee vs solo runs.
    """
    acfgs = [a or AnnealConfig() for a in acfgs]
    if not acfgs:
        return []
    crits = {a.crit_scale for a in acfgs}
    pws = {a.pressure_weight for a in acfgs}
    if model is None and (len(crits) != 1 or len(pws) != 1):
        raise ValueError(
            f"anneal_placements shares one cost model: crit_scale/"
            f"pressure_weight must be uniform, got {crits}/{pws}")
    model = model or build_cost_model(
        g, nx, ny, metric=metric, crit_scale=acfgs[0].crit_scale,
        pressure_weight=acfgs[0].pressure_weight)
    src, dst = edge_endpoints(g)
    return anneal_tables_many(
        g.num_nodes, nx, ny, src, dst, np.asarray(model.w_edge),
        np.asarray(model.w_node), acfgs, inits=inits)


def anneal_placement(
    g: DataflowGraph,
    nx: int,
    ny: int,
    acfg: AnnealConfig | None = None,
    *,
    metric: str = "height",
    init: np.ndarray | None = None,
    model: CostModel | None = None,
    guide=None,
    guide_every: int = 1,
    guide_margin: float = 0.0,
) -> PlacementResult:
    """Search a node -> PE placement for ``g`` on the ``nx x ny`` torus.

    ``init`` defaults to a uniform-random placement drawn from
    ``acfg.seed`` — the baseline the annealer is guaranteed (by best-so-far
    tracking that includes the init) to never score worse than. ``guide``
    (a fitted :class:`~repro.surrogate.model.SurrogateModel` or a prebuilt
    :class:`~repro.surrogate.delta.Guide` for this graph and grid) switches
    on the two-stage surrogate accept — see :func:`anneal_tables`.
    """
    acfg = acfg or AnnealConfig()
    model = model or build_cost_model(
        g, nx, ny, metric=metric, crit_scale=acfg.crit_scale,
        pressure_weight=acfg.pressure_weight)
    src, dst = edge_endpoints(g)
    return anneal_tables(
        g.num_nodes, nx, ny, src, dst, np.asarray(model.w_edge),
        np.asarray(model.w_node), acfg, init=init, guide=guide,
        guide_every=guide_every, guide_margin=guide_margin)
