"""Greedy criticality-sorted local-slot assignment (paper §II-B).

The paper's "static one-time node labeling": given a node -> PE placement and
per-node criticality labels, each PE's local graph memory stores its nodes in
*decreasing* criticality order (node id breaks ties), so the hierarchical
leading-one detector's first hit is the most critical ready node and the RDY
flag vectors stay the only memory overhead (~6%).

This is the canonical implementation used by
:func:`repro.core.partition.build_graph_memory`; it is pure numpy (placement
and packing are one-time host-side steps).
"""
from __future__ import annotations

import numpy as np


def assign_slots(node_pe: np.ndarray, crit: np.ndarray,
                 num_pes: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-PE slot numbers in decreasing-criticality order.

    Args:
      node_pe: [N] node -> PE assignment.
      crit: [N] criticality labels (larger == more critical). Pass
        ``-np.arange(N)`` for a naive node-id-order layout.
      num_pes: PE count (grid size).

    Returns:
      (node_slot [N] int32, local_counts [num_pes] int32).
    """
    node_pe = np.asarray(node_pe)
    n = int(node_pe.shape[0])
    node_slot = np.zeros(n, dtype=np.int32)
    local_counts = np.zeros(num_pes, dtype=np.int32)
    if n == 0:
        return node_slot, local_counts
    # Grouped by PE, sorted by -criticality within each group, id tiebreak.
    order = np.lexsort((np.arange(n), -np.asarray(crit, dtype=np.float64), node_pe))
    pe_sorted = node_pe[order]
    group_start = np.r_[0, np.flatnonzero(np.diff(pe_sorted)) + 1]
    starts = np.zeros(n, dtype=np.int64)
    starts[group_start] = group_start
    starts = np.maximum.accumulate(starts)
    node_slot[order] = (np.arange(n) - starts).astype(np.int32)
    np.add.at(local_counts, node_pe, 1)
    return node_slot, local_counts
