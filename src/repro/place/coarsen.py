"""Multilevel coarsen -> anneal -> refine placement (fig1-full scale).

The PR-3 annealer moves one node per proposal, so a meaningful improvement on
a ~470K-node graph needs a proposal budget that grows with N — intractable as
a direct search (ROADMAP). The multilevel pipeline makes it tractable the way
large-graph partitioners (and ReGraph-style HBM graph systems) do:

  1. **Coarsen** (:func:`cluster_nodes`): criticality-aware greedy heavy-edge
     clustering collapses the graph ~16-64x. Edges are visited in decreasing
     criticality weight (ties broken by edge id — fully deterministic), and
     endpoints are merged under a cluster-size cap, so critical chains — the
     latency-bound traffic — fold *inside* clusters first and become free
     local deliveries no matter where the cluster lands.
  2. **Anneal coarse** (:func:`repro.place.anneal.anneal_tables`): the
     existing batched parallel-tempering placer runs unchanged on the cluster
     quotient graph — every proposal now moves a whole cluster, so the same
     proposal budget covers ~ratio x more of the search space.
  3. **Uncoarsen + refine**: the cluster placement projects back to nodes
     (``node_pe = cluster_pe[clusters]``) and an optional bounded fine-grained
     anneal polishes single-node details from that warm start.

Determinism: clustering is host-side numpy with stable sorts and integer
keys; both anneal levels are the PR-3 bit-deterministic kernel. For a fixed
config the whole pipeline is bit-reproducible across machines — which is what
lets ``BENCH_overlay.json`` gate multilevel placement *cycle counts* in CI.
With identity clusters (``clusters=np.arange(N)``) the quotient tables carry
exactly the original edge weights, so the coarse anneal IS the PR-3 annealer,
bit-for-bit (asserted in ``tests/test_coarsen.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph import DataflowGraph
from .anneal import PlacementResult, anneal_placement, anneal_tables
from .cost import build_cost_model, edge_tables
from .spec import AnnealConfig


@dataclasses.dataclass(frozen=True)
class MultilevelResult:
    """Final fine placement plus per-level diagnostics."""

    node_pe: np.ndarray            # [N] int32 node -> PE (after refinement)
    clusters: np.ndarray           # [N] int32 node -> cluster id
    num_clusters: int
    coarse: PlacementResult        # cluster-level anneal result
    cost: int                      # fine-level integer cost of node_pe
    projected_cost: int            # fine cost right after uncoarsening
    refined: PlacementResult | None  # fine-level refinement pass (or None)

    @property
    def refine_improvement(self) -> float:
        return 1.0 - self.cost / max(1, self.projected_cost)


def cluster_nodes(
    g: DataflowGraph,
    ratio: int = 32,
    *,
    metric: str = "height",
    crit_scale: int = 3,
) -> np.ndarray:
    """[N] int32 node -> cluster ids, ~``ratio`` nodes per cluster.

    Greedy heavy-edge agglomeration under a size cap: edges are processed in
    decreasing criticality weight (edge-id tiebreak, stable — deterministic),
    and the two endpoint clusters merge whenever the union stays within
    ``ratio`` nodes. Critical chains therefore collapse first, which is the
    criticality-aware part: the quotient graph keeps latency-bound edges
    internal. Cluster ids are compacted to 0..C-1 in first-node order.
    """
    if ratio < 1:
        raise ValueError(f"coarsen ratio must be >= 1, got {ratio}")
    n = g.num_nodes
    parent = np.arange(n, dtype=np.int64)
    size = np.ones(n, dtype=np.int64)

    def find(v: int) -> int:
        root = v
        while parent[root] != root:
            root = parent[root]
        while parent[v] != root:   # path compression
            parent[v], v = root, parent[v]
        return root

    if ratio > 1:
        src, dst, w_edge, _ = edge_tables(g, metric=metric,
                                          crit_scale=crit_scale)
        order = np.lexsort((np.arange(len(w_edge)), -w_edge.astype(np.int64)))
        for e in order:
            a, b = find(int(src[e])), find(int(dst[e]))
            if a != b and size[a] + size[b] <= ratio:
                if size[a] < size[b]:   # union by size
                    a, b = b, a
                parent[b] = a
                size[a] += size[b]

    roots = np.fromiter((find(v) for v in range(n)), dtype=np.int64, count=n)
    # Compact to dense ids in order of first appearance (node-id order).
    _, first_idx, compact = np.unique(roots, return_index=True,
                                      return_inverse=True)
    remap = np.argsort(np.argsort(first_idx, kind="stable"), kind="stable")
    return remap[compact].astype(np.int32)


def quotient_tables(
    g: DataflowGraph,
    clusters: np.ndarray,
    *,
    metric: str = "height",
    crit_scale: int = 3,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Cluster-level ``(src, dst, w_edge, w_node)`` quotient tables.

    Parallel inter-cluster edges aggregate their integer weights; cluster
    weights are the sums of member node weights; intra-cluster edges vanish
    (their hops are 0 wherever the cluster lands). With identity clusters the
    tables are cost-equivalent to the fine graph's — every weight sum is
    preserved — which is what makes the identity-coarsened anneal bit-exact.
    """
    src, dst, w_edge, w_node = edge_tables(g, metric=metric,
                                           crit_scale=crit_scale)
    clusters = np.asarray(clusters, dtype=np.int64)
    c = int(clusters.max(initial=-1)) + 1
    csrc, cdst = clusters[src], clusters[dst]
    cross = csrc != cdst
    csrc, cdst, w = csrc[cross], cdst[cross], w_edge[cross].astype(np.int64)
    # Aggregate parallel edges: sum weights per (src, dst) cluster pair.
    pair = csrc * c + cdst
    uniq, inv = np.unique(pair, return_inverse=True)
    w_agg = np.zeros(len(uniq), dtype=np.int64)
    np.add.at(w_agg, inv, w)
    cw = np.zeros(c, dtype=np.int64)
    np.add.at(cw, clusters, w_node.astype(np.int64))
    return ((uniq // c).astype(np.int32), (uniq % c).astype(np.int32),
            w_agg.astype(np.int32), cw.astype(np.int32))


def default_refine(acfg: AnnealConfig) -> AnnealConfig:
    """Bounded default polish: a fraction of the coarse budget spent on
    single-node moves from the projected warm start."""
    return dataclasses.replace(acfg, replicas=min(4, acfg.replicas),
                               rounds=max(1, acfg.rounds // 4))


def multilevel_anneal(
    g: DataflowGraph,
    nx: int,
    ny: int,
    acfg: AnnealConfig | None = None,
    *,
    ratio: int = 32,
    refine: AnnealConfig | str | None = "auto",
    clusters: np.ndarray | None = None,
    metric: str = "height",
    guide=None,
    guide_every: int = 1,
    guide_margin: float = 0.0,
) -> MultilevelResult:
    """Coarsen ``g`` ~``ratio``x, anneal cluster moves, project back, refine.

    ``acfg`` budgets the *coarse* anneal (cluster-level moves); ``refine``
    budgets a bounded fine-grained anneal warm-started from the projected
    placement — ``"auto"`` (the default, same as an unset
    ``PlacementSpec.refine``) derives :func:`default_refine` from ``acfg``,
    an explicit ``None`` skips refinement entirely (the projected placement
    is returned as-is). ``clusters`` overrides the clustering (e.g.
    ``np.arange(N)`` degenerates to the plain PR-3 annealer, bit-exactly).

    ``guide`` (a fitted fine-level :class:`~repro.surrogate.model
    .SurrogateModel` or :class:`~repro.surrogate.delta.Guide`) turns on the
    two-stage surrogate accept at *both* levels: the coarse phase consults
    ``guide.coarsen(clusters)`` — whose quotient features are bit-exactly
    the fine features of the projected placement, so coarse gate decisions
    are exactly the fine surrogate's verdict on the projected move — and
    the refinement phase consults the fine guide directly.
    """
    acfg = acfg or AnnealConfig()
    if isinstance(refine, str):
        if refine != "auto":
            raise ValueError(f"refine must be an AnnealConfig, None, or "
                             f"'auto'; got {refine!r}")
        refine = default_refine(acfg)
    if clusters is None:
        clusters = cluster_nodes(g, ratio, metric=metric,
                                 crit_scale=acfg.crit_scale)
    clusters = np.asarray(clusters, dtype=np.int32)
    if clusters.shape != (g.num_nodes,):
        raise ValueError(
            f"clusters must be [{g.num_nodes}] node->cluster, "
            f"got {clusters.shape}")
    csrc, cdst, cw_edge, cw_node = quotient_tables(
        g, clusters, metric=metric, crit_scale=acfg.crit_scale)
    c = int(cw_node.shape[0])

    coarse_guide = None
    if guide is not None:
        from ..surrogate.delta import Guide, build_guide

        if not isinstance(guide, Guide):
            guide = build_guide(guide)
        coarse_guide = guide.coarsen(clusters)
    coarse = anneal_tables(c, nx, ny, csrc, cdst, cw_edge, cw_node, acfg,
                           guide=coarse_guide, guide_every=guide_every,
                           guide_margin=guide_margin)
    node_pe = coarse.node_pe[clusters].astype(np.int32)

    model = build_cost_model(g, nx, ny, metric=metric,
                             crit_scale=acfg.crit_scale,
                             pressure_weight=acfg.pressure_weight)
    projected_cost = int(model.cost(node_pe))

    refined = None
    if refine is not None:
        refined = anneal_placement(g, nx, ny, refine, metric=metric,
                                   init=node_pe, model=model, guide=guide,
                                   guide_every=guide_every,
                                   guide_margin=guide_margin)
        node_pe = refined.node_pe

    return MultilevelResult(
        node_pe=node_pe,
        clusters=clusters,
        num_clusters=c,
        coarse=coarse,
        cost=int(refined.cost) if refined is not None else projected_cost,
        projected_cost=projected_cost,
        refined=refined,
    )
