"""repro.place — batched NoC-aware placement & mapping subsystem.

The paper's performance story starts *before* the first simulated cycle: a
static one-time labeling/placement step decides which PE owns each dataflow
node and in what criticality order each PE's local memory lists them (§II).
This package makes that step a first-class, searchable subsystem:

  * :mod:`.spec`   — hashable placement/annealer configs
    (:class:`PlacementSpec` rides inside ``OverlayConfig``);
  * :mod:`.cost`   — integer, fully vmapped placement cost model
    (hop-weighted Hoplite-torus traffic + criticality-weighted slot
    pressure): thousands of candidates score as one ``jax.vmap`` batch;
  * :mod:`.anneal` — batched parallel-tempering / threshold-accepting placer
    whose propose/accept loop runs under ``lax.scan`` with per-replica
    temperatures; bit-deterministic for a fixed key;
  * :mod:`.coarsen` — multilevel coarsen -> anneal -> refine pipeline:
    criticality-aware clustering collapses the graph ~16-64x so the annealer
    moves whole clusters — placement search at fig1-full (~470K node) scale;
  * :mod:`.slots`  — the greedy criticality-sorted slot assigner that
    reproduces the paper's node-labeling memory layout;
  * :mod:`.api`    — resolution + engine integration (``graph_memory``,
    cycle-count evaluation incl. the sharded ``simulate_batch_sharded``
    path, and the config hillclimb behind ``benchmarks/hillclimb.py``).

Identity placement (``OverlayConfig(placement=None)``) is the default
everywhere and is bit-identical to the pre-subsystem engine — committed
benchmark cycle counts do not move unless a placement is asked for.
"""
from .anneal import (  # noqa: F401
    GuidedPlacementResult,
    PlacementResult,
    anneal_placement,
    anneal_placements,
    anneal_tables,
    anneal_tables_many,
)
from .api import (  # noqa: F401
    HILLCLIMB_SPACE,
    config_hillclimb,
    evaluate_placements,
    graph_memory,
    graph_memory_for_config,
    resolve,
    shape_class,
    simulate_placements,
    uniform_graph_memories,
)
from .coarsen import (  # noqa: F401
    MultilevelResult,
    cluster_nodes,
    multilevel_anneal,
    quotient_tables,
)
from .cost import CostModel, build_cost_model, edge_tables, torus_hops  # noqa: F401
from .slots import assign_slots  # noqa: F401
from .spec import AnnealConfig, PlacementSpec, coerce  # noqa: F401
from .spec import resolve as resolve_spec  # noqa: F401
