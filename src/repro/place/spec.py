"""Hashable placement specifications.

These are the *configuration* half of :mod:`repro.place`: plain frozen
dataclasses with scalar fields only, so a spec can ride inside
:class:`repro.core.overlay.OverlayConfig` (a ``jax.jit`` static argument) and
key memoization caches. The *mechanism* half (cost model, annealer, slot
assigner) lives in the sibling modules and consumes these specs.

Deliberately import-free of the rest of the package: ``core.overlay`` imports
this module at trace time, so it must never pull the simulator back in.
"""
from __future__ import annotations

import dataclasses

#: Strategies resolvable without search: the identity default plus every
#: static heuristic in :func:`repro.core.partition.place_nodes`.
STATIC_STRATEGIES = (
    "identity", "round_robin", "blocked", "random", "clustered",
    "bulk_clustered", "critical_chain",
)
SEARCH_STRATEGIES = ("anneal", "multilevel")


@dataclasses.dataclass(frozen=True)
class AnnealConfig:
    """Knobs of the batched parallel-tempering placer (:mod:`.anneal`).

    The accept rule is *threshold accepting* (Dueck & Scheuer's deterministic
    simulated-annealing variant): replica ``r`` accepts any move whose integer
    cost delta is ``<= threshold[r]``. Thresholds ladder geometrically from
    ``t_max`` down to 0 (replica 0 is a pure greedy descender) and stay fixed
    while parallel-tempering swaps migrate good configurations toward the
    cold end every round. With integer costs this makes the whole search
    bit-deterministic across machines and XLA versions — a requirement for
    the CI-gated placement cycle counts in ``BENCH_overlay.json``.
    """

    replicas: int = 8          # parallel-tempering ladder size
    rounds: int = 24           # swap/best-tracking epochs
    steps: int = 512           # proposals per replica per round (lax.scan)
    t_max: float = 64.0        # hottest acceptance threshold (integer-cost units)
    pressure_weight: int = 1   # slot-pressure term weight (integer)
    crit_scale: int = 3        # max extra integer weight for critical edges/nodes
    seed: int = 0              # PRNG key for proposals + the random init

    def __post_init__(self):
        if self.replicas < 1 or self.rounds < 1 or self.steps < 1:
            raise ValueError(f"replicas/rounds/steps must be >= 1, got {self}")
        if self.t_max < 0:
            raise ValueError(f"t_max must be >= 0, got {self.t_max}")


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Names how nodes map onto the PE grid.

    ``strategy`` is ``"identity"`` (keep the partitioner's default
    round-robin — the layout every committed benchmark cycle count was
    recorded with), any static heuristic from
    :func:`repro.core.partition.place_nodes`, ``"anneal"`` (NoC-aware
    search: random init from ``seed``, improved by :func:`repro.place.anneal`
    under ``anneal`` knobs), or ``"multilevel"`` (coarsen ~``coarsen_ratio``x,
    anneal cluster moves under ``anneal`` knobs, uncoarsen, then refine under
    ``refine`` knobs — the fig1-full-scale pipeline in
    :mod:`repro.place.coarsen`). ``metric`` picks the criticality labeling
    used for slot assignment and the cost model's weights.

    ``guide="surrogate"`` upgrades either search strategy to the two-stage
    surrogate-guided accept (knobs ``guide_every`` / ``guide_margin`` /
    ``guide_train`` below; mechanism in :mod:`repro.surrogate.delta`); in
    the multilevel pipeline both the coarse cluster-level phase and the
    fine refinement are guided.
    """

    strategy: str = "identity"
    seed: int = 0
    metric: str = "height"
    anneal: AnnealConfig | None = None
    #: starting point for "anneal": "random" (the baseline the placer is
    #: guaranteed to never score worse than) or any static strategy.
    init: str = "random"
    #: "anneal"/"multilevel" only: ``"surrogate"`` switches on the two-stage
    #: accept — a ridge surrogate fitted on ``guide_train`` self-generated
    #: simulated placements (:func:`repro.surrogate.fit_from_sim`, seeded
    #: from ``seed``) pre-screens every proposal via exact O(degree)
    #: incremental features, and only promising moves reach the integer
    #: cost rule. ``None`` (default) is the plain PR-3/PR-4 search.
    guide: str | None = None
    #: guided only: apply the surrogate gate on every k-th proposal of a
    #: sweep (1 = every proposal; larger values leave the off-steps
    #: unguided for extra exploration).
    guide_every: int = 1
    #: guided only: accept threshold on the predicted cycle delta — moves
    #: predicted to add more than this many cycles are rejected before the
    #: cost rule. 0.0 = only predicted-non-worsening moves; ``inf``
    #: disables the gate (bit-identical to the unguided annealer).
    guide_margin: float = 0.0
    #: guided only: simulated training placements for the auto-fitted
    #: surrogate when :func:`repro.place.api.resolve` has to fit one.
    guide_train: int = 24
    #: "multilevel" only: target nodes per cluster for the coarsening pass
    #: (the graph collapses ~coarsen_ratio x before the coarse anneal).
    coarsen_ratio: int = 32
    #: "multilevel" only: budget of the bounded fine-grained refinement
    #: anneal after uncoarsening (None = the small default derived from
    #: ``anneal`` by :func:`repro.place.coarsen.default_refine`).
    refine: AnnealConfig | None = None

    def __post_init__(self):
        known = STATIC_STRATEGIES + SEARCH_STRATEGIES
        if self.strategy not in known:
            raise ValueError(
                f"unknown placement strategy {self.strategy!r}; known: {known}")
        if self.init not in STATIC_STRATEGIES:
            raise ValueError(
                f"unknown anneal init strategy {self.init!r}; "
                f"known: {STATIC_STRATEGIES}")
        if self.anneal is not None and not isinstance(self.anneal, AnnealConfig):
            raise TypeError(f"anneal must be an AnnealConfig, got {self.anneal!r}")
        if self.refine is not None and not isinstance(self.refine, AnnealConfig):
            raise TypeError(f"refine must be an AnnealConfig, got {self.refine!r}")
        if self.coarsen_ratio < 1:
            raise ValueError(
                f"coarsen_ratio must be >= 1, got {self.coarsen_ratio}")
        if self.guide not in (None, "surrogate"):
            raise ValueError(
                f"unknown guide {self.guide!r}; known: None, 'surrogate'")
        if self.guide is not None and self.strategy not in SEARCH_STRATEGIES:
            # Silently ignoring the guide on a static strategy would let a
            # "guided" benchmark quietly run an unguided placement.
            raise ValueError(
                f"guide={self.guide!r} requires a search strategy "
                f"{SEARCH_STRATEGIES}, got strategy={self.strategy!r}")
        if self.guide_every < 1:
            raise ValueError(
                f"guide_every must be >= 1, got {self.guide_every}")
        if self.guide_train < 2:
            raise ValueError(
                f"guide_train must be >= 2, got {self.guide_train}")

    @property
    def anneal_config(self) -> AnnealConfig:
        return self.anneal if self.anneal is not None else AnnealConfig(seed=self.seed)


IDENTITY = PlacementSpec()


def resolve(placement) -> PlacementSpec:
    """Normalize any user-facing placement value to a :class:`PlacementSpec`.

    Accepts ``None`` (identity), a strategy-name string, or a spec. This is
    the single resolution point for ``str | PlacementSpec | None``:
    ``OverlayConfig.__post_init__`` runs every ``placement=`` through it, so
    downstream code (the engines, :mod:`repro.place.api`, the service layer)
    only ever sees canonical specs — two configs that mean the same layout
    compare and hash equal, which keeps ``jax.jit`` static-argument caches
    and the service content-hash keys from fragmenting on spelling.
    """
    if placement is None:
        return IDENTITY
    if isinstance(placement, str):
        return PlacementSpec(strategy=placement)
    if isinstance(placement, PlacementSpec):
        return placement
    raise TypeError(
        f"placement must be None, a strategy name, or a PlacementSpec; "
        f"got {placement!r}")


#: Backwards-compatible alias — ``resolve`` is the canonical name.
coerce = resolve
