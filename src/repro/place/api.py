"""Placement resolution + overlay integration entry points.

This is the layer the simulator and the benchmarks talk to:

  * :func:`resolve` turns a :class:`~repro.place.spec.PlacementSpec` (or a
    strategy name, or ``None`` = identity) into a concrete ``[N]`` node -> PE
    vector;
  * :func:`graph_memory` / :func:`graph_memory_for_config` pack a placed
    graph into the :class:`~repro.core.partition.GraphMemory` the engines
    consume (criticality-sorted slots via :mod:`repro.place.slots`);
  * :func:`evaluate_placements` scores candidate placements by *simulated
    cycle count* — single device or sharded over a mesh, batching the config
    axis through ``simulate_batch`` / ``simulate_batch_sharded``;
  * :func:`config_hillclimb` is the greedy coordinate-descent search over
    (placement x scheduler x select latency x eject capacity) that
    ``benchmarks/hillclimb.py --overlay`` fronts.

Heavyweight imports (overlay, distributed) are deferred into the functions:
``core.overlay`` itself imports this package for placement threading, so the
module level must stay cycle-free.
"""
from __future__ import annotations

import time

import numpy as np

from ..core.graph import DataflowGraph
from .anneal import anneal_placement
from .spec import PlacementSpec
from .spec import resolve as resolve_spec


def resolve(g: DataflowGraph, nx: int, ny: int, placement=None, *,
            guide_model=None) -> np.ndarray:
    """[N] node -> PE vector for ``placement`` on the ``nx x ny`` grid.

    ``placement`` is a PlacementSpec, a strategy name, an explicit [N] array
    (returned as-is), or ``None`` (identity = the partitioner's default
    round-robin — the layout all committed benchmark numbers use).

    A spec with ``guide="surrogate"`` runs the search with the two-stage
    surrogate accept; ``guide_model`` supplies a prefitted
    :class:`~repro.surrogate.model.SurrogateModel` for it (must match this
    graph and grid), otherwise one is fitted on the spot from
    ``spec.guide_train`` self-generated simulated placements.
    """
    if isinstance(placement, np.ndarray):
        return placement.astype(np.int32)
    from ..core import partition

    spec = resolve_spec(placement)
    num_pes = nx * ny
    guide = None
    if spec.guide == "surrogate":  # spec validation pins strategy to a search
        guide = guide_model
        if guide is None:
            from .. import surrogate as sg

            guide, _, _ = sg.fit_from_sim(
                g, nx, ny, n_train=spec.guide_train, seed=spec.seed,
                metric=spec.metric,
                crit_scale=spec.anneal_config.crit_scale)
    if spec.strategy == "anneal":
        init = None  # anneal_placement defaults to random-from-seed
        if spec.init != "random":
            init = resolve(g, nx, ny, PlacementSpec(strategy=spec.init,
                                                    seed=spec.seed))
        return anneal_placement(
            g, nx, ny, spec.anneal_config, metric=spec.metric,
            init=init, guide=guide, guide_every=spec.guide_every,
            guide_margin=spec.guide_margin).node_pe
    if spec.strategy == "multilevel":
        from .coarsen import multilevel_anneal

        return multilevel_anneal(
            g, nx, ny, spec.anneal_config, ratio=spec.coarsen_ratio,
            refine=spec.refine if spec.refine is not None else "auto",
            metric=spec.metric, guide=guide, guide_every=spec.guide_every,
            guide_margin=spec.guide_margin).node_pe
    strategy = "round_robin" if spec.strategy == "identity" else spec.strategy
    return partition.place_nodes(g, num_pes, strategy, seed=spec.seed)


def graph_memory(g: DataflowGraph, nx: int, ny: int, placement=None, *,
                 criticality_order: bool = True, metric: str | None = None):
    """Resolve ``placement`` and pack the per-PE graph memory."""
    from ..core import partition

    spec = (resolve_spec(placement)
            if not isinstance(placement, np.ndarray) else None)
    node_pe = resolve(g, nx, ny, placement)
    return partition.build_graph_memory(
        g, nx, ny, placement=node_pe,
        metric=metric or (spec.metric if spec else "height"),
        criticality_order=criticality_order)


def graph_memory_for_config(g: DataflowGraph, nx: int, ny: int, cfg):
    """GraphMemory for an :class:`~repro.core.overlay.OverlayConfig`:
    honors ``cfg.placement`` and the scheduler's preferred memory layout."""
    from ..core import schedulers

    wants = schedulers.get(cfg.scheduler).wants_criticality_order
    return graph_memory(g, nx, ny, cfg.placement, criticality_order=wants)


def uniform_graph_memories(g: DataflowGraph, nx: int, ny: int, node_pes,
                           *, criticality_order: bool = True,
                           metric: str = "height",
                           pad_lmax: bool = True,
                           min_lmax: int = 0, min_emax: int = 0) -> list:
    """Pack one GraphMemory per ``[N]`` node -> PE vector, all with identical
    array shapes.

    Slot depth (``lmax``) and per-PE edge capacity (``emax``) depend on the
    placement, so naively packed candidate memories differ in shape and every
    ``jax.jit``-ed engine call retraces — scoring k candidates used to
    compile k times. Padding every memory to the candidate-set maxima makes
    the shapes (and thus the jit cache key) identical, so the whole set runs
    through ONE compiled program.

    ``pad_lmax=False`` keeps each memory's own slot depth (only ``emax`` is
    unified) — needed when a scheduler *models* latency from the memory depth
    (the ``scan`` policy's word-count sweep), where padding would change
    cycle counts.

    ``metric`` is one criticality metric for the whole set or one per
    placement (slot ordering only — it never moves the unified shapes).

    ``min_lmax`` / ``min_emax`` raise the padding floor beyond this set's
    own maxima. This is how *different graphs* share one jit cache entry:
    the service batch executor computes the shape maxima across a whole
    query group (:func:`shape_class`) and packs every graph's memories to
    that shared class, so mixed-graph query batches compile once per shape
    class instead of once per graph. Padding never moves cycle counts
    (asserted in tests — empty slots/edges are inert).
    """
    from ..core.partition import build_graph_memory, packed_shape

    node_pes = [np.asarray(p, dtype=np.int32) for p in node_pes]
    metrics = ([metric] * len(node_pes) if isinstance(metric, str)
               else list(metric))
    if len(metrics) != len(node_pes):
        raise ValueError(
            f"need one metric or one per placement; got {len(metrics)} "
            f"for {len(node_pes)} placements")
    # Shapes come from the packer's own derivation (partition.packed_shape),
    # so the identical-shapes guarantee cannot drift from the packing rule.
    shapes = [packed_shape(g, pe, nx * ny) for pe in node_pes]
    lmax = max([l for l, _ in shapes] + [min_lmax, 1])
    emax = max([e for _, e in shapes] + [min_emax, 1])
    return [build_graph_memory(
        g, nx, ny, placement=pe, metric=m,
        criticality_order=criticality_order,
        min_lmax=lmax if pad_lmax else 0, min_emax=emax)
        for pe, m in zip(node_pes, metrics)]


def shape_class(graphs_and_pes, nx: int, ny: int) -> tuple[int, int]:
    """Shared ``(lmax, emax)`` padding floor for a mixed-graph query group.

    ``graphs_and_pes`` is an iterable of ``(DataflowGraph, [N] node_pe)``
    pairs. The returned maxima, fed to :func:`uniform_graph_memories` (or
    :func:`evaluate_placements`) as ``min_lmax`` / ``min_emax``, put every
    graph's packed memory in ONE padded shape class, so the batched engine's
    jit cache holds one entry for the whole group — the shape-churn fix for
    query batches that mix graphs.
    """
    from ..core.partition import packed_shape

    lmax, emax = 1, 1
    for g, pe in graphs_and_pes:
        l, e = packed_shape(g, np.asarray(pe, dtype=np.int32), nx * ny)
        lmax, emax = max(lmax, l), max(emax, e)
    return lmax, emax


def _latency_depends_on_words(cfg_list) -> bool:
    """True when any config's exposed select latency is a function of the
    RDY word count (e.g. the ``scan`` policy) — lmax padding would then be a
    *model* change, not just an engine one."""
    from ..core import schedulers

    return any(schedulers.get(c.scheduler).sel_lat(c, 1)
               != schedulers.get(c.scheduler).sel_lat(c, 2)
               for c in cfg_list)


def simulate_placements(g: DataflowGraph, nx: int, ny: int, node_pes, cfg=None,
                        *, mesh=None, criticality_order: bool = True,
                        metric: str = "height") -> list:
    """Simulated :class:`~repro.core.overlay.SimResult` per ``[N]`` vector.

    The candidate memories are shape-unified (:func:`uniform_graph_memories`)
    so the whole set executes through one compiled program — this is the bulk
    evaluation path the surrogate training set is generated with.
    """
    from ..core import distributed, overlay

    cfg = cfg or overlay.OverlayConfig()
    gms = uniform_graph_memories(
        g, nx, ny, node_pes, criticality_order=criticality_order,
        metric=metric, pad_lmax=not _latency_depends_on_words([cfg]))
    out = []
    for gm in gms:
        if mesh is None:
            out.append(overlay._simulate_batch(gm, [cfg])[0])
        else:
            out.append(distributed._simulate_batch_sharded(gm, mesh, [cfg])[0])
    return out


def evaluate_placements(g: DataflowGraph, nx: int, ny: int, placements,
                        cfgs=None, mesh=None, *, prune: str | None = None,
                        keep_top: int = 8, surrogate=None,
                        surrogate_train: int = 24,
                        min_lmax: int = 0, min_emax: int = 0) -> dict:
    """Score candidate placements by simulated cycle count.

    Args:
      placements: ``{name: spec | strategy | [N] array}``.
      cfgs: one OverlayConfig, a sequence of them (swept per placement via
        the batched engine), or None for the default config.
      mesh: optional ``jax.sharding.Mesh`` — evaluation then runs through
        ``simulate_sharded`` / ``simulate_batch_sharded`` with the PE grid
        tiled over the mesh (placement evaluation for overlays larger than
        one device).
      prune: ``"surrogate"`` ranks every candidate with the cheap
        cycle-prediction model from :mod:`repro.surrogate` and simulates only
        the ``keep_top`` best-predicted ones (the returned dict then contains
        just those names). ``surrogate`` supplies a fitted
        :class:`~repro.surrogate.model.SurrogateModel` (it must have been
        built for this graph and grid — a mismatch raises); ``None`` fits one
        on the spot from ``surrogate_train`` self-generated simulated
        placements (``repro.surrogate.fit_from_sim``). With a config *sweep*,
        the ranking (and any on-the-spot fit) follows ``cfg_list[0]`` only —
        one pruned candidate set serves every config, so a placement that
        excels only under a later config can be pruned away; prune per
        config in separate calls when that matters.
      min_lmax, min_emax: raise the candidate memories' padding floor so
        *separate* calls over different graphs land in one padded shape
        class and reuse one compiled program (see :func:`shape_class`).

    Returns:
      ``{name: SimResult}`` (or ``{name: [SimResult, ...]}`` with a config
      sweep). Candidate memories are shape-unified
      (:func:`uniform_graph_memories`) so the batched engine compiles once
      for the whole candidate set, not once per placement.
    """
    from ..core import distributed, overlay, schedulers

    single = cfgs is None or not isinstance(cfgs, (list, tuple))
    cfg_list = [cfgs or overlay.OverlayConfig()] if single else list(cfgs)
    wants_set = {schedulers.get(c.scheduler).wants_criticality_order
                 for c in cfg_list}
    if len(wants_set) != 1:
        # One packed memory per placement serves the whole sweep; mixed
        # layout preferences would silently skew non-first schedulers.
        raise ValueError(
            "evaluate_placements needs schedulers with a uniform "
            "wants_criticality_order per call; split the config sweep by "
            "memory layout")
    wants = wants_set.pop()

    names = list(placements)
    node_pes = [resolve(g, nx, ny, placements[k]) for k in names]
    # Slot ordering honors each spec's own criticality metric (explicit
    # arrays have no spec and take the default), exactly like graph_memory.
    metrics = [resolve_spec(placements[k]).metric
               if not isinstance(placements[k], np.ndarray) else "height"
               for k in names]

    if prune is not None:
        if prune != "surrogate":
            raise ValueError(f"unknown prune mode {prune!r}; "
                             f"known: 'surrogate'")
        from .. import surrogate as sg

        model = surrogate
        if model is None:
            # mesh rides along: an overlay that needs the sharded path for
            # candidate sims needs it for the training sims too.
            model, _, _ = sg.fit_from_sim(
                g, nx, ny, cfg=cfg_list[0], n_train=surrogate_train,
                mesh=mesh)
        keep = model.rank(np.stack(node_pes))[:max(1, keep_top)]
        names = [names[i] for i in keep]
        node_pes = [node_pes[i] for i in keep]
        metrics = [metrics[i] for i in keep]

    gms = uniform_graph_memories(
        g, nx, ny, node_pes, criticality_order=wants, metric=metrics,
        pad_lmax=not _latency_depends_on_words(cfg_list),
        min_lmax=min_lmax, min_emax=min_emax)
    # The memories are already placed, so cfg.placement is dead weight here —
    # but it is a jit *static* argument, and two sweeps differing only in the
    # spec they were resolved from would needlessly compile twice. Strip it
    # to the canonical identity so equal-shape candidate sets share one
    # compiled program no matter which placement specs produced them.
    import dataclasses as _dc
    cfg_list = [_dc.replace(c, placement=None) for c in cfg_list]
    out = {}
    for name, gm in zip(names, gms):
        if mesh is None:
            res = overlay._simulate_batch(gm, cfg_list)
        else:
            res = distributed._simulate_batch_sharded(gm, mesh, cfg_list)
        out[name] = res[0] if single else res
    return out


# ---------------------------------------------------------------------------
# Greedy coordinate-descent over the overlay config space (incl. placement).
# ---------------------------------------------------------------------------

#: Axes of the overlay-config search space; ``scheduler`` is filled from the
#: policy registry at call time.
HILLCLIMB_SPACE = {
    "placement": ["round_robin", "clustered", "bulk_clustered",
                  "critical_chain", "anneal"],
    "scheduler": None,
    "select_latency": [None, 1, 2, 4],
    "eject_capacity": [1, 2],
}


def config_hillclimb(g: DataflowGraph, nx: int, ny: int, *,
                     max_cycles: int = 4_000_000, seed: int = 0,
                     space: dict | None = None) -> dict:
    """Greedy coordinate descent, one batched program per neighborhood group.

    Each step proposes every single-axis change to the current config;
    unseen neighbors sharing a (placement, eject capacity, memory layout)
    triple evaluate through ONE ``simulate_batch`` call. Placement axes
    resolve through :func:`resolve` (so ``"anneal"`` runs the placer once
    and reuses the result). Returns a machine-readable record:
    trajectory, best config, best cycles, evaluation count, wall seconds.
    """
    from ..core import schedulers
    from ..core.overlay import OverlayConfig, _simulate_batch

    space = dict(space or HILLCLIMB_SPACE)
    if space.get("scheduler") is None:
        space["scheduler"] = sorted(schedulers.REGISTRY)

    placed: dict = {}    # strategy -> node_pe
    gms: dict = {}       # (strategy, wants_criticality_order) -> GraphMemory

    def gm_for(strategy, wants):
        key = (strategy, wants)
        if key not in gms:
            if strategy not in placed:
                placed[strategy] = resolve(
                    g, nx, ny, PlacementSpec(strategy=strategy, seed=seed))
            gms[key] = graph_memory(g, nx, ny, placed[strategy],
                                    criticality_order=wants)
        return gms[key]

    n_evals = [0]
    seen: dict = {}  # config tuple -> cycles (configs revisit across steps)

    def evaluate(points):
        """[{axis: value}] -> [cycles] (inf when the config never finishes,
        so the search just steps around it)."""
        key = lambda pt: tuple(sorted(pt.items(), key=lambda kv: kv[0]))
        cycles = [seen.get(key(pt)) for pt in points]
        groups: dict = {}
        for i, pt in enumerate(points):
            if cycles[i] is None:
                wants = schedulers.get(pt["scheduler"]).wants_criticality_order
                groups.setdefault(
                    (pt["placement"], pt["eject_capacity"], wants), []).append(i)
        for (strategy, eject, wants), idxs in groups.items():
            n_evals[0] += len(idxs)
            cfgs = [OverlayConfig(scheduler=points[i]["scheduler"],
                                  select_latency=points[i]["select_latency"],
                                  eject_capacity=eject,
                                  max_cycles=max_cycles) for i in idxs]
            for i, r in zip(idxs, _simulate_batch(gm_for(strategy, wants),
                                                  cfgs)):
                c = r.cycles if r.done else float("inf")
                cycles[i] = seen[key(points[i])] = c
        return cycles

    def _finite(c):
        return None if c == float("inf") else c

    current = dict(placement="round_robin", scheduler="ooo",
                   select_latency=None, eject_capacity=1)
    t0 = time.time()
    best = evaluate([current])[0]
    trajectory = [{"config": dict(current), "cycles": _finite(best)}]
    while True:
        neighbors = []
        for field, values in space.items():
            for v in values:
                if v != current[field]:
                    neighbors.append(dict(current, **{field: v}))
        res = evaluate(neighbors)
        j = min(range(len(neighbors)), key=res.__getitem__)
        if res[j] >= best:
            break
        current, best = neighbors[j], res[j]
        trajectory.append({"config": dict(current), "cycles": _finite(best)})

    return {
        "space": {k: [str(v) for v in vs] for k, vs in space.items()},
        "trajectory": trajectory,
        "best_config": current,
        "best_cycles": _finite(best),
        "evaluations": n_evals[0],
        "wall_s": round(time.time() - t0, 3),
    }
