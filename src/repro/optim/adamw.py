"""AdamW with fp32 master weights, decoupled weight decay and global-norm
gradient clipping. Optimizer state is a pytree mirroring params, so the FSDP
sharding rules apply verbatim (ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

OptState = dict  # {"m": tree, "v": tree, "master": tree|None, "count": i32}


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Any], Any] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_weights: bool = True

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }
        if self.master_weights:
            # copy=True: with f32 params astype would alias the param buffer
            # and break buffer donation in jitted train steps.
            state["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        return state

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        count = state["count"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)) + 1e-16)
        scale = jnp.minimum(1.0, self.clip_norm / gnorm)
        gf = jax.tree.map(lambda g: g * scale, gf)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], gf)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], gf)
        c = count.astype(jnp.float32)
        mhat_s = 1.0 / (1 - b1 ** c)
        vhat_s = 1.0 / (1 - b2 ** c)
        lr = self._lr(count)

        base = state.get("master", params)

        def step_fn(p32, mm, vv):
            upd = (mm * mhat_s) / (jnp.sqrt(vv * vhat_s) + self.eps)
            return p32.astype(jnp.float32) * (1 - lr * self.weight_decay) - lr * upd

        new_master = jax.tree.map(step_fn, base, m, v)
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        new_state = {"m": m, "v": v, "count": count}
        if self.master_weights:
            new_state["master"] = new_master
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
