from .adamw import AdamW, OptState  # noqa: F401
from .schedule import wsd_schedule, cosine_schedule  # noqa: F401
