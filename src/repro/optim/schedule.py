"""LR schedules. WSD (warmup-stable-decay) is MiniCPM's schedule and the
default for all training configs; cosine provided for comparison."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    """Warmup (linear) -> stable (constant peak) -> decay (exponential to
    final_frac * peak). Step counts are in optimizer steps."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        decay_mult = jnp.power(jnp.asarray(final_frac, jnp.float32), in_decay)
        return jnp.where(step < warmup, warm, peak_lr * decay_mult)

    return lr


def cosine_schedule(peak_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return lr
