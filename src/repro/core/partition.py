"""Node -> PE placement and per-PE local graph memory construction.

This reproduces the paper's memory organization: each PE holds a *local graph
memory* of node records, laid out in **decreasing criticality order** so the
leading-one detector's first hit is the most critical ready node (§II-B).

The packed image (:class:`GraphMemory`) is the only thing the simulator sees;
every per-cycle update is local to one PE row, which is what makes the overlay
shard_map-able across real devices.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .criticality import criticality as _criticality
from .graph import DataflowGraph

FLAGS_PER_WORD = 32  # paper: 32 of the 40 BRAM bits hold RDY flags


@dataclasses.dataclass(frozen=True)
class GraphMemory:
    """Per-PE packed view of a placed dataflow graph.

    All arrays are numpy; the overlay converts to jnp. P = nx*ny PEs.

    Node records, [P, lmax] (padded with valid=False):
      opcode, fanin, init_value, fo_base (into the per-PE edge arrays),
      fo_count, valid.
    Edge records, [P, emax]:
      e_dst_pe, e_dst_slot (local slot at destination PE), e_dst_opidx.
    node_pe/node_slot: [N] global -> (pe, slot) map (for reading results back).
    """

    nx: int
    ny: int
    lmax: int
    emax: int
    words: int
    opcode: np.ndarray
    fanin: np.ndarray
    init_value: np.ndarray
    fo_base: np.ndarray
    fo_count: np.ndarray
    valid: np.ndarray
    e_dst_pe: np.ndarray
    e_dst_slot: np.ndarray
    e_dst_opidx: np.ndarray
    node_pe: np.ndarray
    node_slot: np.ndarray
    local_counts: np.ndarray

    @property
    def num_pes(self) -> int:
        return self.nx * self.ny

    @property
    def num_nodes(self) -> int:
        return int(self.node_pe.shape[0])


def place_nodes(
    g: DataflowGraph,
    num_pes: int,
    strategy: str = "round_robin",
    seed: int = 0,
    cluster: int = 16,
) -> np.ndarray:
    """[N] node -> PE assignment.

    ``clustered``: beyond-paper locality optimization — consecutive node-id
    segments (which follow the generator's block structure) are confined to
    small PE clusters laid out as square tiles of the grid, so most dataflow
    edges travel ~sqrt(cluster) NoC hops instead of ~grid-diameter. See
    EXPERIMENTS.md §Perf (overlay iterations).
    """
    n = g.num_nodes
    if strategy == "round_robin":
        return (np.arange(n) % num_pes).astype(np.int32)
    if strategy == "blocked":
        per = math.ceil(n / num_pes)
        return (np.arange(n) // per).astype(np.int32)
    if strategy == "random":
        rng = np.random.default_rng(seed)
        return rng.integers(0, num_pes, size=n).astype(np.int32)
    if strategy == "clustered":
        ny = int(math.sqrt(num_pes))            # grid assumed square (16x16)
        nx = num_pes // ny
        ts = max(1, int(math.sqrt(cluster)))    # tile side (4 for cluster=16)
        tiles_x, tiles_y = max(1, nx // ts), max(1, ny // ts)
        k = tiles_x * tiles_y                   # number of tile clusters
        seg = max(1, math.ceil(n / (4 * k)))    # ~4 segments per cluster
        ids = np.arange(n)
        cl = (ids // seg) % k
        w = ids % (ts * ts)
        cx, cy = cl // tiles_y, cl % tiles_y
        wx, wy = w // ts, w % ts
        return ((cx * ts + wx) * ny + (cy * ts + wy)).astype(np.int32)
    if strategy == "bulk_clustered":
        # Beyond-paper iter 4: bulk traffic is bandwidth-bound -> confine it
        # to small PE tiles (short hops); the critical chain is latency- and
        # injection-bound -> keep it round-robin across the whole grid.
        c = _criticality(g, "height")
        frac = 0.05
        n_chain = max(num_pes, int(n * frac))
        order = np.argsort(-c, kind="stable")
        chain, bulk = order[:n_chain], order[n_chain:]
        pe = np.empty(n, dtype=np.int32)
        pe[chain] = (np.arange(n_chain) % num_pes).astype(np.int32)
        sub = place_nodes_clustered_ids(len(bulk), num_pes, cluster)
        pe[bulk] = sub
        return pe
    if strategy == "critical_chain":
        # Beyond-paper: the critical chain is latency-bound, the bulk is
        # bandwidth-bound. Place successive high-criticality nodes on the
        # SAME PE (chain links become 1-cycle local deliveries), strided
        # across the grid; spread the bulk round-robin.
        c = _criticality(g, "height")
        frac = 0.05
        n_chain = max(num_pes, int(n * frac))
        order = np.argsort(-c, kind="stable")
        chain = order[:n_chain]
        pe = np.empty(n, dtype=np.int32)
        chunk = max(1, math.ceil(n_chain / num_pes))
        stride = 37 % num_pes or 1              # coprime stride spreads chunks
        pe[chain] = ((np.arange(n_chain) // chunk) * stride % num_pes).astype(np.int32)
        bulk = order[n_chain:]
        pe[bulk] = (np.arange(n - n_chain) % num_pes).astype(np.int32)
        return pe
    raise ValueError(f"unknown placement strategy {strategy!r}")


def place_nodes_clustered_ids(n: int, num_pes: int, cluster: int = 16) -> np.ndarray:
    """Clustered-tile assignment for ``n`` consecutive ids (helper)."""
    ny = int(math.sqrt(num_pes))
    nx = num_pes // ny
    ts = max(1, int(math.sqrt(cluster)))
    tiles_x, tiles_y = max(1, nx // ts), max(1, ny // ts)
    k = tiles_x * tiles_y
    seg = max(1, math.ceil(n / (4 * k)))
    ids = np.arange(n)
    cl = (ids // seg) % k
    w = ids % (ts * ts)
    cx, cy = cl // tiles_y, cl % tiles_y
    wx, wy = w // ts, w % ts
    return ((cx * ts + wx) * ny + (cy * ts + wy)).astype(np.int32)


def packed_shape(g: DataflowGraph, node_pe: np.ndarray,
                 num_pes: int) -> tuple[int, int]:
    """Pre-padding ``(lmax, emax)`` that :func:`build_graph_memory` packs for
    ``node_pe`` — the single source of the shape derivation, shared with
    ``repro.place.uniform_graph_memories`` so its identical-shapes guarantee
    cannot drift out of sync with the packing rule."""
    node_pe = np.asarray(node_pe)
    counts = np.zeros(num_pes, dtype=np.int64)
    np.add.at(counts, node_pe, 1)
    ecounts = np.zeros(num_pes, dtype=np.int64)
    np.add.at(ecounts, node_pe, g.fanout_count().astype(np.int64))
    return int(counts.max(initial=1)), max(1, int(ecounts.max(initial=1)))


def build_graph_memory(
    g: DataflowGraph,
    nx: int,
    ny: int,
    *,
    placement: str | np.ndarray = "round_robin",
    metric: str = "height",
    criticality_order: bool = True,
    seed: int = 0,
    min_lmax: int = 0,
    min_emax: int = 0,
) -> GraphMemory:
    """Place ``g`` on an ``nx x ny`` PE grid and pack local memories.

    ``placement`` is a strategy name (see :func:`place_nodes`) or an explicit
    ``[N]`` node -> PE vector — e.g. one produced by the NoC-aware placer in
    :mod:`repro.place` (``repro.place.graph_memory`` is the convenience
    wrapper that resolves a ``PlacementSpec`` and calls this).

    ``criticality_order=True`` sorts each PE's local memory in decreasing
    criticality (the paper's static heuristic); ``False`` keeps node-id order
    (what a naive layout would do) — useful for ablations.

    ``min_lmax`` / ``min_emax`` pad the packed slot depth / per-PE edge
    capacity beyond what this placement needs, so memories packed for
    *different* placements of the same graph come out with identical array
    shapes — the jitted engines then reuse one compiled program across the
    whole candidate set (see ``repro.place.evaluate_placements``). Padding
    slots are ``valid=False`` and padding edge words are never addressed, so
    results are unchanged — but note the ``scan`` policy *models* its select
    latency as the RDY word count, so a deeper padded memory is a
    (deliberately) slower scanned memory under that policy.
    """
    # Lazy: repro.place depends on core modules; keep the cycle import-free.
    from ..place.slots import assign_slots

    num_pes = nx * ny
    n = g.num_nodes
    if isinstance(placement, np.ndarray):
        node_pe = placement.astype(np.int32)
        if node_pe.shape != (n,):
            raise ValueError(
                f"explicit placement must be [{n}] node->PE, got {node_pe.shape}")
        if n and (node_pe.min() < 0 or node_pe.max() >= num_pes):
            raise ValueError(
                f"placement references PEs outside the {nx}x{ny} grid")
    else:
        node_pe = place_nodes(g, num_pes, placement, seed)
    c = _criticality(g, metric) if criticality_order else -np.arange(n, dtype=np.int64)

    # Local slot assignment: per PE, decreasing criticality, node id tiebreak
    # (the paper's node-labeling step — see repro.place.slots).
    node_slot, local_counts = assign_slots(node_pe, c, num_pes)

    lmax_nat, emax_nat = packed_shape(g, node_pe, num_pes)
    lmax = max(lmax_nat, int(min_lmax))
    words = max(1, math.ceil(lmax / FLAGS_PER_WORD))
    lmax_padded = words * FLAGS_PER_WORD

    def per_node(arr, fill, dtype):
        out = np.full((num_pes, lmax_padded), fill, dtype=dtype)
        out[node_pe, node_slot] = arr
        return out

    opcode = per_node(g.opcode, 0, np.int8)
    fanin = per_node(g.fanin_count(), 0, np.int8)
    init_value = per_node(g.initial_values, 0.0, np.float32)
    valid = np.zeros((num_pes, lmax_padded), dtype=bool)
    valid[node_pe, node_slot] = True

    # Per-PE edge arrays: edges grouped by (pe, slot-order of source node).
    fo_cnt_global = g.fanout_count()
    fo_count = per_node(fo_cnt_global, 0, np.int32)
    fo_base = np.zeros((num_pes, lmax_padded), dtype=np.int32)
    emax = max(emax_nat, int(min_emax))

    e_dst_pe = np.zeros((num_pes, emax), dtype=np.int32)
    e_dst_slot = np.zeros((num_pes, emax), dtype=np.int32)
    e_dst_opidx = np.zeros((num_pes, emax), dtype=np.int8)

    # Sort nodes per PE by local slot; lay their fanout lists contiguously.
    slot_order = np.lexsort((node_slot, node_pe))
    cursor = np.zeros(num_pes, dtype=np.int64)
    ptr, dst, slt = g.fanout_ptr, g.fanout_dst, g.fanout_slot
    for v in slot_order:
        p = node_pe[v]
        lo, hi = ptr[v], ptr[v + 1]
        k = hi - lo
        base = cursor[p]
        fo_base[p, node_slot[v]] = base
        if k:
            d = dst[lo:hi]
            e_dst_pe[p, base:base + k] = node_pe[d]
            e_dst_slot[p, base:base + k] = node_slot[d]
            e_dst_opidx[p, base:base + k] = slt[lo:hi]
            cursor[p] = base + k

    return GraphMemory(
        nx=nx, ny=ny, lmax=lmax_padded, emax=emax, words=words,
        opcode=opcode, fanin=fanin, init_value=init_value,
        fo_base=fo_base, fo_count=fo_count, valid=valid,
        e_dst_pe=e_dst_pe, e_dst_slot=e_dst_slot, e_dst_opidx=e_dst_opidx,
        node_pe=node_pe, node_slot=node_slot, local_counts=local_counts,
    )


# ---------------------------------------------------------------------------
# Memory-cost model (paper §II-B and §III) — used by benchmarks/table1.
# ---------------------------------------------------------------------------

M20K_BITS = 20 * 1024
BRAM_WORDS = 512          # 512 x 40b configuration
BRAM_WIDTH_BITS = 40
BRAMS_PER_PE = 8          # "our TDP design is composed of 8 BRAMs/processor"
NODE_RECORD_WORDS = 4     # opcode/meta + 2 operand slots + result
EDGE_RECORD_WORDS = 1     # dst node + operand slot pack into one 40b word


def rdy_flag_overhead() -> float:
    """Fraction of graph-memory words spent on RDY bit-flag vectors.

    Paper: per 512-word BRAM, 2 * ceil(512/32) = 32 words of flags (~6.25%).
    """
    per_bram = 2 * math.ceil(BRAM_WORDS / FLAGS_PER_WORD)
    return per_bram / BRAM_WORDS


def fifo_worst_case_words(local_words: int) -> int:
    """Deadlock-free FIFO depth: every addressable local word could hold a
    simultaneously-ready node, so depth == graph-memory word count."""
    return int(local_words)


def capacity_elements(num_pes: int, scheduler: str,
                      edge_per_node: float = 1.5) -> dict:
    """On-chip graph capacity (nodes + edges) under each scheduler.

    In-order (prior TDPs): FIFOs live in *dedicated* BRAMs (a hardware FIFO
    cannot share ports with graph memory) and deadlock-freedom needs TWO
    worst-case queues (compute-ready ids + fanout-pending ids), each as deep
    as the addressable local node space. Solving g + 2g <= 8 gives 2 graph
    BRAMs + 6 FIFO BRAMs per PE — which is what pins the paper's in-order
    256-PE overlay at ~100K nodes+edges.

    OoO (this paper): no FIFOs; 2 x ceil(512/32) = 32 flag words per BRAM
    (~6.25%), everything else stores the graph -> ~5x capacity.
    """
    if scheduler == "inorder":
        graph_brams = BRAMS_PER_PE // (1 + 2)  # g + 2g <= 8 -> g = 2
        words = graph_brams * BRAM_WORDS * num_pes
        fifo_words = (BRAMS_PER_PE - graph_brams) * BRAM_WORDS * num_pes
    elif scheduler == "ooo":
        words = int(BRAMS_PER_PE * BRAM_WORDS * (1 - rdy_flag_overhead())) * num_pes
        fifo_words = 0
    else:
        raise ValueError(scheduler)
    # words = N * NODE_RECORD_WORDS + E * EDGE_RECORD_WORDS, E = r*N
    n = words / (NODE_RECORD_WORDS + edge_per_node * EDGE_RECORD_WORDS)
    return {
        "graph_words": int(words),
        "fifo_words": int(fifo_words),
        "nodes": int(n),
        "elements": int(n * (1 + edge_per_node)),
    }
