"""Cycle-accurate token-dataflow overlay simulator (paper §II), in JAX.

The whole simulation is one compiled XLA program: a ``lax.while_loop`` whose
body advances every PE and every Hoplite router by one cycle. All per-cycle
updates are local to a PE row (the paper's "local graph memory"), which is
what lets :mod:`repro.core.distributed` run the same body under ``shard_map``
with ppermute torus hops.

Timing model (faithful to §II):
  * one packet ejected per PE per cycle, one packet injected per PE per cycle
    (subject to NoC arbitration);
  * ALU latency 1 cycle (single-stage pipelined DSP), folded into fire;
  * scheduler select latency: 1 cycle for the in-order FIFO pop, 2 cycles for
    the hierarchical OuterLOD/InnerLOD pick ("deterministic 2-cycle process");
  * Hoplite: 1 cycle per hop, deflection on contention.

Schedulers:
  * ``inorder`` — ready nodes queue in a FIFO in arrival order (FCFS), the
    baseline of prior TDP designs. FIFO depth = worst case (all local nodes).
  * ``ooo``     — packed RDY bit-flags + hierarchical leading-one detect; with
    criticality-ordered local memory, the pick is the most critical ready
    node. (the paper's contribution)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import bitvec, noc
from .graph import DIV_EPS, OP_ADD, OP_DIV, OP_MUL, OP_SUB
from .partition import GraphMemory

Shift = Callable[[dict], dict]


def alu(opcode, a, b):
    """Vectorized ALU — identical semantics to graph.apply_op (f32)."""
    safe_b = b + jnp.where(b >= 0, jnp.float32(DIV_EPS), jnp.float32(-DIV_EPS))
    return jnp.select(
        [opcode == OP_ADD, opcode == OP_SUB, opcode == OP_MUL, opcode == OP_DIV],
        [a + b, a - b, a * b, a / safe_b],
        jnp.float32(0),
    )


@dataclasses.dataclass(frozen=True)
class OverlayConfig:
    """``select_latency`` models the scheduler pick cost in *exposed* cycles.

    The paper's hierarchical LOD is a deterministic 2-cycle circuit — the
    point of determinism is that the pick pipelines behind the (>=1 cycle)
    fanout drain of the previous node, so its exposed cost equals the FIFO
    pop's: 1 cycle. Default is therefore 1 for both schedulers; pass
    ``select_latency=2`` to model an un-pipelined LOD (ablation), or a larger
    value to model the naive non-deterministic memory scan the paper rejects.
    """

    scheduler: str = "ooo"           # "ooo" | "inorder"
    select_latency: int | None = None  # exposed cycles; default 1
    eject_capacity: int = 1          # 2 == paper §II-C BRAM multipumping
    max_cycles: int = 1_000_000

    @property
    def sel_lat(self) -> int:
        return 1 if self.select_latency is None else self.select_latency


class DeviceGraph(dict):
    """GraphMemory as jnp arrays reshaped to [nx, ny, ...]."""


def device_graph(gm: GraphMemory) -> DeviceGraph:
    nx, ny = gm.nx, gm.ny
    r3 = lambda a: jnp.asarray(a).reshape(nx, ny, -1)
    return DeviceGraph(
        opcode=r3(gm.opcode).astype(jnp.int32),
        fanin=r3(gm.fanin).astype(jnp.int32),
        init_value=r3(gm.init_value),
        fo_base=r3(gm.fo_base).astype(jnp.int32),
        fo_count=r3(gm.fo_count).astype(jnp.int32),
        valid=r3(gm.valid),
        e_dst_pe=r3(gm.e_dst_pe).astype(jnp.int32),
        e_dst_slot=r3(gm.e_dst_slot).astype(jnp.int32),
        e_dst_opidx=r3(gm.e_dst_opidx).astype(jnp.int32),
    )


def _row_gather(arr, idx):
    """arr: [nx, ny, L(, ...)], idx: [nx, ny] -> arr[x, y, idx[x, y]]."""
    idxc = jnp.clip(idx, 0, arr.shape[2] - 1)
    take = jnp.take_along_axis(arr, idxc.reshape(*idx.shape, 1, *(1,) * (arr.ndim - 3)), axis=2)
    return take.reshape(idx.shape + arr.shape[3:])


def init_state(g: DeviceGraph, cfg: OverlayConfig, fifo_depth: int):
    nx, ny, L = g["opcode"].shape
    W = L // bitvec.FLAGS_PER_WORD
    is_input = (g["fanin"] == 0) & g["valid"]
    has_fo = g["fo_count"] > 0
    computed = is_input
    value = jnp.where(is_input, g["init_value"], 0.0)

    slots = jnp.arange(L, dtype=jnp.int32)
    need_drain = is_input & has_fo  # inputs with fanouts are ready at cycle 0
    # RDY bit image of need_drain.
    bit = (jnp.uint32(1) << (31 - (slots % 32)).astype(jnp.uint32))
    masks = jnp.where(need_drain, bit[None, None, :], jnp.uint32(0))
    rdy = jnp.zeros((nx, ny, W), jnp.uint32)
    rdy = rdy.at[:, :, :].set(
        jax.lax.reduce(
            masks.reshape(nx, ny, W, 32), jnp.uint32(0), jax.lax.bitwise_or, (3,)
        )
    )
    # FIFO pre-loaded with ready inputs in ascending slot (== arrival) order.
    order_key = jnp.where(need_drain, slots, L)
    fifo_init = jnp.sort(order_key, axis=-1)[:, :, :fifo_depth]
    fifo = jnp.where(fifo_init < L, fifo_init, -1).astype(jnp.int32)
    fifo_size = need_drain.sum(axis=-1).astype(jnp.int32)

    return dict(
        pending=g["fanin"].astype(jnp.int32),
        operands=jnp.zeros((nx, ny, L, 2), jnp.float32),
        computed=computed,
        value=value,
        rdy=rdy if cfg.scheduler == "ooo" else jnp.zeros((nx, ny, W), jnp.uint32),
        fifo=fifo if cfg.scheduler == "inorder" else jnp.full((nx, ny, 1), -1, jnp.int32),
        fifo_head=jnp.zeros((nx, ny), jnp.int32),
        fifo_size=fifo_size if cfg.scheduler == "inorder" else jnp.zeros((nx, ny), jnp.int32),
        active=jnp.full((nx, ny), -1, jnp.int32),
        cursor=jnp.zeros((nx, ny), jnp.int32),
        cursor_end=jnp.zeros((nx, ny), jnp.int32),
        sel_wait=jnp.full((nx, ny), cfg.sel_lat - 1, jnp.int32),
        link_e=noc.empty_packets(nx, ny),
        link_s=noc.empty_packets(nx, ny),
        cycle=jnp.int32(0),
        delivered=jnp.int32(0),
        deflections=jnp.int32(0),
        busy_cycles=jnp.int32(0),
        done=jnp.bool_(False),
    )


def make_cycle_fn(
    g: DeviceGraph,
    cfg: OverlayConfig,
    *,
    shift_e: Shift = noc.roll_shift_e,
    shift_s: Shift = noc.roll_shift_s,
    all_reduce: Callable[[Any], Any] = lambda x: x,
    x0=0,
    y0=0,
    global_ny: int | None = None,
):
    """Build the one-cycle transition function. ``all_reduce`` reduces scalar
    termination predicates across shards (identity on a single device);
    ``x0``/``y0``/``global_ny`` supply global router coordinates when the PE
    grid is sharded (see core.distributed)."""
    nx, ny, L = g["opcode"].shape
    ny_i32 = jnp.int32(global_ny if global_ny is not None else ny)

    def cycle(s):
        # ---- 1. offer injection packet from the active node's fanout cursor
        inj_valid = (s["active"] >= 0) & (s["cursor"] < s["cursor_end"])
        dst_pe = _row_gather(g["e_dst_pe"], s["cursor"])
        inject = dict(
            valid=inj_valid,
            dst_x=dst_pe // ny_i32,
            dst_y=dst_pe % ny_i32,
            dst_slot=_row_gather(g["e_dst_slot"], s["cursor"]),
            opidx=_row_gather(g["e_dst_opidx"], s["cursor"]),
            value=_row_gather(s["value"], s["active"]),
        )

        # ---- 2. NoC cycle
        link_e, link_s, ejects, accepted = noc.router_cycle(
            s["link_e"], s["link_s"], inject, shift_e=shift_e, shift_s=shift_s,
            x0=x0, y0=y0, eject_capacity=cfg.eject_capacity,
        )

        # ---- 3. advance fanout cursor; retire drained nodes
        cursor = s["cursor"] + accepted.astype(jnp.int32)
        cursor_end = s["cursor_end"]
        drained = (s["active"] >= 0) & (cursor >= cursor_end)
        active = jnp.where(drained, -1, s["active"])
        sel_wait = jnp.where(drained, cfg.sel_lat - 1, s["sel_wait"])

        # ---- 4. apply ejected packets (eject_capacity per PE per cycle)
        ix = jnp.arange(nx)[:, None] * jnp.ones((1, ny), jnp.int32)
        iy = jnp.arange(ny)[None, :] * jnp.ones((nx, 1), jnp.int32)
        pending, operands = s["pending"], s["operands"]
        computed, value = s["computed"], s["value"]
        rdy = s["rdy"]
        fifo, fifo_head, fifo_size = s["fifo"], s["fifo_head"], s["fifo_size"]
        n_delivered = jnp.int32(0)
        n_fired = jnp.int32(0)

        for eject in ejects:
            ej_v = eject["valid"]
            ej_slot = jnp.clip(eject["dst_slot"], 0, L - 1)
            ej_op = jnp.clip(eject["opidx"], 0, 1)
            old_opnd = operands[ix, iy, ej_slot, ej_op]
            operands = operands.at[ix, iy, ej_slot, ej_op].set(
                jnp.where(ej_v, eject["value"], old_opnd)
            )
            old_pend = pending[ix, iy, ej_slot]
            new_pend = jnp.where(ej_v, old_pend - 1, old_pend)
            pending = pending.at[ix, iy, ej_slot].set(new_pend)

            was_done = computed[ix, iy, ej_slot]
            fired = ej_v & (new_pend == 0) & ~was_done
            a = operands[ix, iy, ej_slot, 0]
            b = operands[ix, iy, ej_slot, 1]
            opc = g["opcode"][ix, iy, ej_slot]
            fval = alu(opc, a, b)
            value = value.at[ix, iy, ej_slot].set(
                jnp.where(fired, fval, value[ix, iy, ej_slot])
            )
            computed = computed.at[ix, iy, ej_slot].set(was_done | fired)

            ready_new = fired & (g["fo_count"][ix, iy, ej_slot] > 0)
            if cfg.scheduler == "ooo":
                rdy = bitvec.set_bit(
                    rdy.reshape(nx * ny, -1),
                    (ix * ny + iy).reshape(-1),
                    ej_slot.reshape(-1),
                    ready_new.reshape(-1),
                ).reshape(nx, ny, -1)
            else:
                depth = fifo.shape[-1]
                tail = (fifo_head + fifo_size) % depth
                old_f = fifo[ix, iy, tail]
                fifo = fifo.at[ix, iy, tail].set(jnp.where(ready_new, ej_slot, old_f))
                fifo_size = fifo_size + ready_new.astype(jnp.int32)
            n_delivered = n_delivered + ej_v.sum().astype(jnp.int32)
            n_fired = n_fired + fired.sum().astype(jnp.int32)

        # ---- 5. scheduler: select the next node on idle PEs
        idle = active < 0
        if cfg.scheduler == "ooo":
            cand = bitvec.leading_one(rdy)          # most critical ready slot
            have = cand >= 0
        else:
            cand = _row_gather(fifo, fifo_head)
            have = fifo_size > 0
        can_wait = idle & have & (sel_wait > 0)
        sel_wait = jnp.where(can_wait, sel_wait - 1, sel_wait)
        sel = idle & have & (sel_wait == 0) & ~can_wait
        if cfg.scheduler == "ooo":
            # clear the selected bit
            word, mask = bitvec.slot_word_mask(jnp.clip(cand, 0, L - 1))
            row = rdy[ix, iy, word]
            rdy = rdy.at[ix, iy, word].set(jnp.where(sel, row & ~mask, row))
        else:
            depth = fifo.shape[-1]
            fifo_head = jnp.where(sel, (fifo_head + 1) % depth, fifo_head)
            fifo_size = jnp.where(sel, fifo_size - 1, fifo_size)

        active = jnp.where(sel, cand, active)
        new_base = _row_gather(g["fo_base"], jnp.clip(cand, 0, L - 1))
        new_cnt = _row_gather(g["fo_count"], jnp.clip(cand, 0, L - 1))
        cursor = jnp.where(sel, new_base, cursor)
        cursor_end = jnp.where(sel, new_base + new_cnt, cursor_end)

        # ---- 6. termination + stats
        all_computed = all_reduce((computed | ~g["valid"]).all())
        no_ready = all_reduce((rdy == 0).all() & (fifo_size == 0).all())
        no_active = all_reduce((active < 0).all())
        links_idle = all_reduce(noc.links_empty(link_e, link_s))
        done = all_computed & no_ready & no_active & links_idle

        return dict(
            pending=pending, operands=operands, computed=computed, value=value,
            rdy=rdy, fifo=fifo, fifo_head=fifo_head, fifo_size=fifo_size,
            active=active, cursor=cursor, cursor_end=cursor_end, sel_wait=sel_wait,
            link_e=link_e, link_s=link_s,
            cycle=s["cycle"] + 1,
            delivered=s["delivered"] + all_reduce(n_delivered).astype(jnp.int32),
            deflections=s["deflections"]
            + all_reduce((inj_valid & ~accepted).sum()).astype(jnp.int32),
            busy_cycles=s["busy_cycles"] + all_reduce(n_fired).astype(jnp.int32),
            done=done,
        )

    return cycle


@dataclasses.dataclass
class SimResult:
    cycles: int
    done: bool
    values: np.ndarray        # [N] node values in global id order
    delivered: int
    deflections: int
    busy_cycles: int


@functools.partial(jax.jit, static_argnames=("cfg", "fifo_depth", "nx", "ny"))
def _run_jit(g: dict, cfg: OverlayConfig, fifo_depth: int, nx: int, ny: int):
    state = init_state(g, cfg, fifo_depth)
    cycle_fn = make_cycle_fn(g, cfg)

    def cond(s):
        return (~s["done"]) & (s["cycle"] < cfg.max_cycles)

    final = jax.lax.while_loop(cond, cycle_fn, state)
    return final


def simulate(gm: GraphMemory, cfg: OverlayConfig | None = None) -> SimResult:
    """Run the overlay to completion on a single device."""
    cfg = cfg or OverlayConfig()
    g = device_graph(gm)
    fifo_depth = max(int(gm.local_counts.max(initial=1)), 1)
    final = _run_jit(dict(g), cfg, fifo_depth, gm.nx, gm.ny)
    value = np.asarray(final["value"]).reshape(gm.num_pes, gm.lmax)
    values = value[gm.node_pe, gm.node_slot]
    return SimResult(
        cycles=int(final["cycle"]),
        done=bool(final["done"]),
        values=values,
        delivered=int(final["delivered"]),
        deflections=int(final["deflections"]),
        busy_cycles=int(final["busy_cycles"]),
    )
