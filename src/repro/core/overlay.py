"""Cycle-accurate token-dataflow overlay simulator (paper §II), in JAX.

The whole simulation is one compiled XLA program: a ``lax.while_loop`` whose
body advances every PE and every Hoplite router by one cycle. All per-cycle
updates are local to a PE row (the paper's "local graph memory"), which is
what lets :mod:`repro.core.distributed` run the same body under ``shard_map``
with ppermute torus hops.

Timing model (faithful to §II):
  * one packet ejected per PE per cycle, one packet injected per PE per cycle
    (subject to NoC arbitration);
  * ALU latency 1 cycle (single-stage pipelined DSP), folded into fire;
  * scheduler select latency: policy-dependent exposed cycles (see
    ``OverlayConfig.select_latency`` and each policy's ``sel_lat``);
  * Hoplite: 1 cycle per hop, deflection on contention.

Scheduling policy is pluggable: the cycle kernel only talks to the
:class:`repro.core.schedulers.Scheduler` protocol, and the policy's state
lives in the ``"sched"`` sub-dict of the simulation state pytree. See
:mod:`repro.core.schedulers` for the registered policies (``ooo``,
``inorder``, ``scan``, ``lru_flat``) and how to add one.

Three execution engines share the same cycle body:
  * :func:`simulate`          — single device, one config;
  * :func:`simulate_batch`    — one device, a *stacked* config axis: the body
    is vmapped so an N-scheduler x M-latency sweep is one XLA program
    instead of N*M serial retraces (Fig. 1-style sweeps);
  * :func:`repro.core.distributed.simulate_sharded` — shard_map over a mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bitvec, noc, schedulers
from .graph import DIV_EPS, OP_ADD, OP_DIV, OP_MUL, OP_SUB
from .partition import GraphMemory
from .schedulers import row_gather as _row_gather

Shift = Callable[[dict], dict]


def alu(opcode, a, b):
    """Vectorized ALU — identical semantics to graph.apply_op (f32)."""
    safe_b = b + jnp.where(b >= 0, jnp.float32(DIV_EPS), jnp.float32(-DIV_EPS))
    return jnp.select(
        [opcode == OP_ADD, opcode == OP_SUB, opcode == OP_MUL, opcode == OP_DIV],
        [a + b, a - b, a * b, a / safe_b],
        jnp.float32(0),
    )


@dataclasses.dataclass(frozen=True)
class OverlayConfig:
    """``select_latency`` models the scheduler pick cost in *exposed* cycles.

    The paper's hierarchical LOD is a deterministic 2-cycle circuit — the
    point of determinism is that the pick pipelines behind the (>=1 cycle)
    fanout drain of the previous node, so its exposed cost equals the FIFO
    pop's: 1 cycle. ``None`` defers to the policy's own default (1 for
    ``ooo``/``inorder``/``lru_flat``; the RDY word count for ``scan``, which
    models the un-pipelined memory sweep the paper rejects). Pass
    ``select_latency=2`` to model an un-pipelined LOD (ablation), or larger
    values to widen the exposed scan cost.
    """

    scheduler: str = "ooo"           # any name in schedulers.REGISTRY
    select_latency: int | None = None  # exposed cycles; None = policy default
    eject_capacity: int = 1          # 2 == paper §II-C BRAM multipumping
    max_cycles: int = 1_000_000

    def __post_init__(self):
        if self.select_latency is not None and self.select_latency < 1:
            raise ValueError(
                f"select_latency must be >= 1 exposed cycle (or None for the "
                f"policy default), got {self.select_latency}")

    @property
    def sel_lat(self) -> int:
        return 1 if self.select_latency is None else self.select_latency


class DeviceGraph(dict):
    """GraphMemory as jnp arrays reshaped to [nx, ny, ...]."""


def device_graph(gm: GraphMemory) -> DeviceGraph:
    nx, ny = gm.nx, gm.ny
    r3 = lambda a: jnp.asarray(a).reshape(nx, ny, -1)
    return DeviceGraph(
        opcode=r3(gm.opcode).astype(jnp.int32),
        fanin=r3(gm.fanin).astype(jnp.int32),
        init_value=r3(gm.init_value),
        fo_base=r3(gm.fo_base).astype(jnp.int32),
        fo_count=r3(gm.fo_count).astype(jnp.int32),
        valid=r3(gm.valid),
        e_dst_pe=r3(gm.e_dst_pe).astype(jnp.int32),
        e_dst_slot=r3(gm.e_dst_slot).astype(jnp.int32),
        e_dst_opidx=r3(gm.e_dst_opidx).astype(jnp.int32),
    )


def _resolve(cfg: OverlayConfig, scheduler: schedulers.Scheduler | None):
    return scheduler if scheduler is not None else schedulers.get(cfg.scheduler)


def init_state(g: DeviceGraph, cfg: OverlayConfig,
               scheduler: schedulers.Scheduler | None = None):
    """Policy-agnostic simulation state. Scheduler state is namespaced under
    ``state["sched"]``; the exposed select latency rides along as the
    ``state["sel_lat"]`` scalar so the batched engine can vmap over it."""
    sched = _resolve(cfg, scheduler)
    nx, ny, L = g["opcode"].shape
    is_input = (g["fanin"] == 0) & g["valid"]
    computed = is_input
    value = jnp.where(is_input, g["init_value"], 0.0)
    lat = sched.sel_lat(cfg, L // bitvec.FLAGS_PER_WORD)

    return dict(
        pending=g["fanin"].astype(jnp.int32),
        operands=jnp.zeros((nx, ny, L, 2), jnp.float32),
        computed=computed,
        value=value,
        sched=sched.init(g, cfg),
        active=jnp.full((nx, ny), -1, jnp.int32),
        cursor=jnp.zeros((nx, ny), jnp.int32),
        cursor_end=jnp.zeros((nx, ny), jnp.int32),
        sel_lat=jnp.int32(lat),
        sel_wait=jnp.full((nx, ny), lat - 1, jnp.int32),
        link_e=noc.empty_packets(nx, ny),
        link_s=noc.empty_packets(nx, ny),
        cycle=jnp.int32(0),
        delivered=jnp.int32(0),
        deflections=jnp.int32(0),
        busy_cycles=jnp.int32(0),
        done=jnp.bool_(False),
    )


def make_cycle_fn(
    g: DeviceGraph,
    cfg: OverlayConfig,
    *,
    scheduler: schedulers.Scheduler | None = None,
    shift_e: Shift = noc.roll_shift_e,
    shift_s: Shift = noc.roll_shift_s,
    all_reduce: Callable[[Any], Any] = lambda x: x,
    x0=0,
    y0=0,
    global_ny: int | None = None,
):
    """Build the one-cycle transition function. ``all_reduce`` reduces scalar
    termination predicates across shards (identity on a single device);
    ``x0``/``y0``/``global_ny`` supply global router coordinates when the PE
    grid is sharded (see core.distributed)."""
    sched = _resolve(cfg, scheduler)
    nx, ny, L = g["opcode"].shape
    ny_i32 = jnp.int32(global_ny if global_ny is not None else ny)

    def cycle(s):
        # ---- 1. offer injection packet from the active node's fanout cursor
        inj_valid = (s["active"] >= 0) & (s["cursor"] < s["cursor_end"])
        dst_pe = _row_gather(g["e_dst_pe"], s["cursor"])
        inject = dict(
            valid=inj_valid,
            dst_x=dst_pe // ny_i32,
            dst_y=dst_pe % ny_i32,
            dst_slot=_row_gather(g["e_dst_slot"], s["cursor"]),
            opidx=_row_gather(g["e_dst_opidx"], s["cursor"]),
            value=_row_gather(s["value"], s["active"]),
        )

        # ---- 2. NoC cycle
        link_e, link_s, ejects, accepted = noc.router_cycle(
            s["link_e"], s["link_s"], inject, shift_e=shift_e, shift_s=shift_s,
            x0=x0, y0=y0, eject_capacity=cfg.eject_capacity,
        )

        # ---- 3. advance fanout cursor; retire drained nodes
        cursor = s["cursor"] + accepted.astype(jnp.int32)
        cursor_end = s["cursor_end"]
        drained = (s["active"] >= 0) & (cursor >= cursor_end)
        active = jnp.where(drained, -1, s["active"])
        sel_wait = jnp.where(drained, s["sel_lat"] - 1, s["sel_wait"])

        # ---- 4. apply ejected packets (eject_capacity per PE per cycle)
        ix = jnp.arange(nx)[:, None] * jnp.ones((1, ny), jnp.int32)
        iy = jnp.arange(ny)[None, :] * jnp.ones((nx, 1), jnp.int32)
        pending, operands = s["pending"], s["operands"]
        computed, value = s["computed"], s["value"]
        sched_st = s["sched"]
        n_delivered = jnp.int32(0)
        n_fired = jnp.int32(0)

        for eject in ejects:
            ej_v = eject["valid"]
            ej_slot = jnp.clip(eject["dst_slot"], 0, L - 1)
            ej_op = jnp.clip(eject["opidx"], 0, 1)
            old_opnd = operands[ix, iy, ej_slot, ej_op]
            operands = operands.at[ix, iy, ej_slot, ej_op].set(
                jnp.where(ej_v, eject["value"], old_opnd)
            )
            old_pend = pending[ix, iy, ej_slot]
            new_pend = jnp.where(ej_v, old_pend - 1, old_pend)
            pending = pending.at[ix, iy, ej_slot].set(new_pend)

            was_done = computed[ix, iy, ej_slot]
            fired = ej_v & (new_pend == 0) & ~was_done
            a = operands[ix, iy, ej_slot, 0]
            b = operands[ix, iy, ej_slot, 1]
            opc = g["opcode"][ix, iy, ej_slot]
            fval = alu(opc, a, b)
            value = value.at[ix, iy, ej_slot].set(
                jnp.where(fired, fval, value[ix, iy, ej_slot])
            )
            computed = computed.at[ix, iy, ej_slot].set(was_done | fired)

            ready_new = fired & (g["fo_count"][ix, iy, ej_slot] > 0)
            sched_st = sched.on_ready(sched_st, ix, iy, ej_slot, ready_new)
            n_delivered = n_delivered + ej_v.sum().astype(jnp.int32)
            n_fired = n_fired + fired.sum().astype(jnp.int32)

        # ---- 5. scheduler: select the next node on idle PEs
        idle = active < 0
        cand, have = sched.select(sched_st, idle)
        can_wait = idle & have & (sel_wait > 0)
        sel_wait = jnp.where(can_wait, sel_wait - 1, sel_wait)
        sel = idle & have & (sel_wait == 0) & ~can_wait
        sched_st = sched.commit(sched_st, sel, cand)

        active = jnp.where(sel, cand, active)
        new_base = _row_gather(g["fo_base"], jnp.clip(cand, 0, L - 1))
        new_cnt = _row_gather(g["fo_count"], jnp.clip(cand, 0, L - 1))
        cursor = jnp.where(sel, new_base, cursor)
        cursor_end = jnp.where(sel, new_base + new_cnt, cursor_end)

        # ---- 6. termination + stats
        all_computed = all_reduce((computed | ~g["valid"]).all())
        no_ready = all_reduce(sched.empty(sched_st))
        no_active = all_reduce((active < 0).all())
        links_idle = all_reduce(noc.links_empty(link_e, link_s))
        done = all_computed & no_ready & no_active & links_idle

        return dict(
            pending=pending, operands=operands, computed=computed, value=value,
            sched=sched_st,
            active=active, cursor=cursor, cursor_end=cursor_end,
            sel_lat=s["sel_lat"], sel_wait=sel_wait,
            link_e=link_e, link_s=link_s,
            cycle=s["cycle"] + 1,
            delivered=s["delivered"] + all_reduce(n_delivered).astype(jnp.int32),
            deflections=s["deflections"]
            + all_reduce((inj_valid & ~accepted).sum()).astype(jnp.int32),
            busy_cycles=s["busy_cycles"] + all_reduce(n_fired).astype(jnp.int32),
            done=done,
        )

    return cycle


@dataclasses.dataclass
class SimResult:
    cycles: int
    done: bool
    values: np.ndarray        # [N] node values in global id order
    delivered: int
    deflections: int
    busy_cycles: int


@functools.partial(jax.jit, static_argnames=("cfg", "nx", "ny"))
def _run_jit(g: dict, cfg: OverlayConfig, nx: int, ny: int):
    state = init_state(g, cfg)
    cycle_fn = make_cycle_fn(g, cfg)

    def cond(s):
        return (~s["done"]) & (s["cycle"] < cfg.max_cycles)

    final = jax.lax.while_loop(cond, cycle_fn, state)
    return final


def _unpack_result(final, gm: GraphMemory, b: int | None = None) -> SimResult:
    pick = (lambda a: a[b]) if b is not None else (lambda a: a)
    value = np.asarray(pick(final["value"])).reshape(gm.num_pes, gm.lmax)
    return SimResult(
        cycles=int(pick(final["cycle"])),
        done=bool(pick(final["done"])),
        values=value[gm.node_pe, gm.node_slot],
        delivered=int(pick(final["delivered"])),
        deflections=int(pick(final["deflections"])),
        busy_cycles=int(pick(final["busy_cycles"])),
    )


def simulate(gm: GraphMemory, cfg: OverlayConfig | None = None) -> SimResult:
    """Run the overlay to completion on a single device."""
    cfg = cfg or OverlayConfig()
    g = device_graph(gm)
    final = _run_jit(dict(g), cfg, gm.nx, gm.ny)
    return _unpack_result(final, gm)


# ---------------------------------------------------------------------------
# Batched sweep engine: one XLA program for an entire config sweep.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "names", "nx", "ny"))
def _run_batch_jit(g: dict, cfg: OverlayConfig, names: tuple[str, ...],
                   policy_ids, sel_lats, max_cycs, nx: int, ny: int):
    sched = schedulers.BatchedScheduler(names)

    def init_one(pid, lat):
        s = init_state(g, cfg, scheduler=sched)
        s["sched"]["policy_id"] = pid
        s["sel_lat"] = lat
        s["sel_wait"] = jnp.full_like(s["sel_wait"], lat - 1)
        return s

    state = jax.vmap(init_one)(policy_ids, sel_lats)
    vcycle = jax.vmap(make_cycle_fn(g, cfg, scheduler=sched))

    def body(s):
        new = vcycle(s)
        halted = s["done"] | (s["cycle"] >= max_cycs)

        def freeze(old, upd):
            d = halted.reshape(halted.shape + (1,) * (old.ndim - 1))
            return jnp.where(d, old, upd)

        # Batch elements that finished (or exhausted their own cycle budget)
        # stop evolving, so each element's final cycle count and done flag
        # are exactly what a solo run with the same config would report.
        return jax.tree.map(freeze, s, new)

    def cond(s):
        return ((~s["done"]) & (s["cycle"] < max_cycs)).any()

    return jax.lax.while_loop(cond, body, state)


def simulate_batch(gm: GraphMemory,
                   cfgs: Sequence[OverlayConfig]) -> list[SimResult]:
    """Run one overlay graph under many configs as a single XLA program.

    The cycle body is vmapped over a stacked config axis (policy id, exposed
    select latency, cycle budget), so a Fig. 1-style N-scheduler x M-latency
    sweep compiles once instead of retracing per config. Batch elements that
    finish — or exhaust their own ``max_cycles`` — freeze in place, so every
    returned result is identical to a serial :func:`simulate` call with the
    same config. Sole requirement: all configs share ``eject_capacity`` (it
    changes the traced NoC structure).
    """
    cfgs = list(cfgs)
    if not cfgs:
        return []
    eject = {c.eject_capacity for c in cfgs}
    if len(eject) != 1:
        raise ValueError(f"simulate_batch needs a uniform eject_capacity, got {eject}")
    names: list[str] = []
    for c in cfgs:
        schedulers.get(c.scheduler)  # validate early
        if c.scheduler not in names:
            names.append(c.scheduler)

    base = dataclasses.replace(
        cfgs[0], scheduler=names[0], select_latency=None,
        max_cycles=max(c.max_cycles for c in cfgs))
    g = device_graph(gm)
    num_words = g["opcode"].shape[2] // bitvec.FLAGS_PER_WORD
    policy_ids = jnp.asarray([names.index(c.scheduler) for c in cfgs], jnp.int32)
    sel_lats = jnp.asarray(
        [schedulers.get(c.scheduler).sel_lat(c, num_words) for c in cfgs],
        jnp.int32)
    max_cycs = jnp.asarray([c.max_cycles for c in cfgs], jnp.int32)

    final = _run_batch_jit(dict(g), base, tuple(names), policy_ids, sel_lats,
                           max_cycs, gm.nx, gm.ny)
    return [_unpack_result(final, gm, b) for b in range(len(cfgs))]
