"""Cycle-accurate token-dataflow overlay simulator (paper §II), in JAX.

The whole simulation is one compiled XLA program: a ``lax.while_loop`` whose
body advances every PE and every Hoplite router by one cycle. All per-cycle
updates are local to a PE row (the paper's "local graph memory"), which is
what lets :mod:`repro.core.distributed` run the same body under ``shard_map``
with ppermute torus hops.

Timing model (faithful to §II):
  * one packet ejected per PE per cycle, one packet injected per PE per cycle
    (subject to NoC arbitration);
  * ALU latency 1 cycle (single-stage pipelined DSP), folded into fire;
  * scheduler select latency: policy-dependent exposed cycles (see
    ``OverlayConfig.select_latency`` and each policy's ``sel_lat``);
  * Hoplite: 1 cycle per hop, deflection on contention.

Scheduling policy is pluggable: the cycle kernel only talks to the
:class:`repro.core.schedulers.Scheduler` protocol (its fused per-cycle entry
point is ``step`` — select + latency-gated commit, optionally backed by the
Pallas kernels in :mod:`repro.kernels.lod` via
``OverlayConfig(engine="select")``), and the policy's state lives in the
``"sched"`` sub-dict of the simulation state pytree. See
:mod:`repro.core.schedulers` for the registered policies (``ooo``,
``inorder``, ``scan``, ``lru_flat``) and how to add one.

Hot-path engineering (engine-level, never observable in results):
  * *Fused eject application*: every eject port applies as one stacked
    scatter per state array and fire detection stays in gathered per-port
    form, so a cycle costs O(PEs), not O(PEs x slots); termination tracks a
    remaining-nodes counter instead of reducing the computed plane.
  * *Chunked stepping* (``OverlayConfig.check_every``; autotuned 8-32 from
    graph size, ``1`` = the per-cycle reference engine): ``check_every``
    cycles run back-to-back in a ``lax.scan`` per ``while_loop`` iteration,
    so the termination predicate — and under ``shard_map`` the cross-shard
    psum/pmin — runs once per chunk. A completed overlay is a fixed point of
    the cycle body, so the exact completion cycle is recovered from the
    chunk's per-cycle done trace (see :func:`make_chunk_fn`); results are
    bit-identical for every ``check_every``.
  * *Megakernel chunks* (``OverlayConfig(engine="megakernel")``): the whole
    chunk fuses into ONE ``pallas_call`` with state carried across its K
    cycles in kernel refs (:mod:`repro.kernels.megakernel`); the jnp scan
    above stays the bit-exact reference oracle.

Three execution engines share the same cycle body:
  * :func:`simulate`          — single device, one config;
  * :func:`simulate_batch`    — one device, a *stacked* config axis: the body
    is vmapped so an N-scheduler x M-latency sweep is one XLA program
    instead of N*M serial retraces (Fig. 1-style sweeps);
  * :func:`repro.core.distributed.simulate_sharded` — shard_map over a mesh
    (and :func:`repro.core.distributed.simulate_batch_sharded`, the sharded
    multi-config sweep: vmap inside shard_map).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import bitvec, noc, schedulers
from .graph import DIV_EPS, OP_ADD, OP_DIV, OP_MUL, OP_SUB, DataflowGraph
from .partition import GraphMemory
from .schedulers import row_gather as _row_gather

Shift = Callable[[dict], dict]

#: Chunk execution engines (cycle-exact by construction, see OverlayConfig).
ENGINES = ("jnp", "select", "megakernel")


def alu(opcode, a, b):
    """Vectorized ALU — identical semantics to graph.apply_op (f32)."""
    safe_b = b + jnp.where(b >= 0, jnp.float32(DIV_EPS), jnp.float32(-DIV_EPS))
    return jnp.select(
        [opcode == OP_ADD, opcode == OP_SUB, opcode == OP_MUL, opcode == OP_DIV],
        [a + b, a - b, a * b, a / safe_b],
        jnp.float32(0),
    )


@dataclasses.dataclass(frozen=True)
class OverlayConfig:
    """``select_latency`` models the scheduler pick cost in *exposed* cycles.

    The paper's hierarchical LOD is a deterministic 2-cycle circuit — the
    point of determinism is that the pick pipelines behind the (>=1 cycle)
    fanout drain of the previous node, so its exposed cost equals the FIFO
    pop's: 1 cycle. ``None`` defers to the policy's own default (1 for
    ``ooo``/``inorder``/``lru_flat``; the RDY word count for ``scan``, which
    models the un-pipelined memory sweep the paper rejects). Pass
    ``select_latency=2`` to model an un-pipelined LOD (ablation), or larger
    values to widen the exposed scan cost.

    ``check_every`` is an engine knob, not a model knob: the termination
    predicate (and, sharded, its cross-shard reduction) is evaluated once per
    ``check_every``-cycle chunk instead of once per cycle. Results are
    bit-identical for every value — a completed overlay is a fixed point of
    the cycle function, so the exact completion cycle is recovered from the
    per-cycle done trace recorded inside the chunk. ``None`` autotunes from
    the graph size (8–32); ``1`` forces the legacy cycle-by-cycle reference
    engine.

    ``engine`` picks how a chunk of cycles executes — never *what* it
    computes (all three engines are bit-identical, asserted in tests):

      * ``"jnp"`` (default) — the pure-jnp reference path: one ``lax.scan``
        of the cycle body per chunk;
      * ``"select"`` — the jnp cycle body with the scheduler pick routed
        through the fused Pallas kernels in :mod:`repro.kernels.lod` (one
        VMEM round-trip per pick), for policies that support it;
      * ``"megakernel"`` — the whole ``check_every``-cycle chunk fused into
        ONE ``pallas_call`` (:mod:`repro.kernels.megakernel`): select +
        Hoplite route + fused eject + termination counter with state
        carried across cycles in kernel refs. Sharded engines fall back to
        ``"jnp"`` chunks whenever a mesh axis is >1 (collectives cannot
        live inside a kernel — see docs/megakernel.md).

    On non-TPU backends the Pallas engines run in interpret mode.

    ``eject_policy`` picks the NoC's single-port eject arbitration:
    ``"n_first"`` (Hoplite's N-beats-W default) or ``"priority"`` (the
    criticality-aware W/N pick — see :func:`repro.core.noc.router_cycle`).
    This IS a model knob: cycle counts change under ``"priority"``.

    ``placement`` names how nodes map onto the PE grid when an engine is
    handed a raw :class:`~repro.core.graph.DataflowGraph` (a
    :class:`repro.place.PlacementSpec`, a strategy name, or ``None`` =
    identity — the partitioner's default round-robin, bit-identical to the
    pre-placement-subsystem engine). Whatever spelling is passed,
    ``__post_init__`` normalizes it through :func:`repro.place.spec.resolve`
    so the stored field is ALWAYS a canonical ``PlacementSpec`` — equal
    layouts hash equal as jit static arguments and service cache keys.
    Ignored when the caller passes an already-packed :class:`GraphMemory`.

    ``telemetry`` opts into the in-engine trace layer (a
    :class:`repro.telemetry.TelemetrySpec` or ``None`` = off, the default):
    cycle-resolved (bucketed) integer traces of per-PE occupancy, per-link
    Hoplite utilization and deflections, eject-port contention, scheduler
    ready-set depth / pick position, and wavefront progress, accumulated
    *inside* the jitted cycle loop under ``state["telem"]``. Telemetry is an
    observer, never a model knob: simulated cycles and stats are bit-
    identical with it on or off, and with ``telemetry=None`` the traced
    program is exactly today's (no extra state, no extra ops). See
    :mod:`repro.telemetry` and docs/telemetry.md.
    """

    scheduler: str = "ooo"           # any name in schedulers.REGISTRY
    select_latency: int | None = None  # exposed cycles; None = policy default
    eject_capacity: int = 1          # 2 == paper §II-C BRAM multipumping
    max_cycles: int = 1_000_000
    check_every: int | None = None   # cycles per termination check; None=auto
    eject_policy: str = "n_first"    # NoC eject arbitration (see noc.py)
    placement: Any = None            # PlacementSpec | strategy name | None
    engine: str = "jnp"              # "jnp" | "select" | "megakernel"
    telemetry: Any = None            # TelemetrySpec | None = tracing off

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(
                f"engine must be one of {sorted(ENGINES)}, got {self.engine!r}")
        if self.select_latency is not None and self.select_latency < 1:
            raise ValueError(
                f"select_latency must be >= 1 exposed cycle (or None for the "
                f"policy default), got {self.select_latency}")
        if self.check_every is not None and self.check_every < 1:
            raise ValueError(
                f"check_every must be >= 1 cycle per termination check (or "
                f"None to autotune), got {self.check_every}")
        if self.eject_policy not in ("n_first", "priority"):
            raise ValueError(
                f"eject_policy must be 'n_first' or 'priority', got "
                f"{self.eject_policy!r}")
        from ..place.spec import resolve  # lazy: placement specs live in place
        # Store the canonical spec (raises on malformed values): every
        # downstream consumer — jit static-arg caches, batch uniformity
        # checks, service content hashes — sees one spelling per layout.
        object.__setattr__(self, "placement", resolve(self.placement))
        if self.telemetry is not None:
            from ..telemetry.spec import TelemetrySpec  # lazy, like place.spec
            if not isinstance(self.telemetry, TelemetrySpec):
                raise TypeError(
                    f"telemetry must be a repro.telemetry.TelemetrySpec or "
                    f"None, got {type(self.telemetry).__name__}")

    @property
    def sel_lat(self) -> int:
        return 1 if self.select_latency is None else self.select_latency


def resolve_check_every(cfg: OverlayConfig, nx: int, ny: int, L: int, *,
                        backend: str | None = None,
                        num_devices: int = 1) -> int:
    """Static chunk length for the stepping engine. Any value is cycle-exact;
    the autotune only trades per-chunk overhead against wasted tail cycles
    (up to K-1 extra cycle evaluations after completion).

    Keyed on graph size AND execution target AND engine path:
      * single-device CPU — grows with the slot count (bigger graphs run
        long enough to amortize deep chunks): 8 / 16 / 32;
      * multi-device mesh (``num_devices > 1``) — the chunk also amortizes
        the per-check cross-shard psum/pmin, which dominates regardless of
        graph size (~1.5x on an 8-device CPU mesh): always 32;
      * single-device TPU — the compiled chunk body is cheap relative to the
        host-visible while_loop predicate sync: at least 16;
      * ``engine="megakernel"`` — one kernel dispatch per chunk, so the
        launch amortizes with depth regardless of graph size: always 32;
      * ``engine="select"`` — one Pallas select dispatch per *cycle*; a
        deeper chunk keeps more of them inside one while-loop iteration:
        at least 16.

    ``backend`` defaults to ``jax.default_backend()`` at trace time.
    """
    if cfg.check_every is not None:
        return cfg.check_every
    if num_devices > 1:
        return 32
    if cfg.engine == "megakernel":
        return 32
    slots = nx * ny * L
    base = 8 if slots <= 4_096 else (16 if slots <= 65_536 else 32)
    if cfg.engine == "select":
        base = max(base, 16)
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return max(base, 16)
    return base


class DeviceGraph(dict):
    """GraphMemory as jnp arrays reshaped to [nx, ny, ...]."""


def device_graph(gm: GraphMemory) -> DeviceGraph:
    nx, ny = gm.nx, gm.ny
    r3 = lambda a: jnp.asarray(a).reshape(nx, ny, -1)
    return DeviceGraph(
        opcode=r3(gm.opcode).astype(jnp.int32),
        fanin=r3(gm.fanin).astype(jnp.int32),
        init_value=r3(gm.init_value),
        fo_base=r3(gm.fo_base).astype(jnp.int32),
        fo_count=r3(gm.fo_count).astype(jnp.int32),
        valid=r3(gm.valid),
        e_dst_pe=r3(gm.e_dst_pe).astype(jnp.int32),
        e_dst_slot=r3(gm.e_dst_slot).astype(jnp.int32),
        e_dst_opidx=r3(gm.e_dst_opidx).astype(jnp.int32),
    )


def _resolve(cfg: OverlayConfig, scheduler: schedulers.Scheduler | None):
    return scheduler if scheduler is not None else schedulers.get(cfg.scheduler)


# ---------------------------------------------------------------------------
# Stat-counter registry. Each entry is a monotone int32 *scalar* counter in
# the simulation state: zero-initialized, incremented by the cycle body with
# a cross-shard ``all_reduce`` on the per-cycle delta, and repaired once per
# chunk as ``start + all_reduce(end - start)`` (see make_chunk_fn). Because
# a completed overlay is a fixed point of the cycle body, every registered
# counter's increment must be zero once ``done`` holds — that is what lets
# the guard-free chunk engines over-simulate past completion without drift.
# Register new counters here (telemetry, future schedulers); the chunk
# repair, init_state, the megakernel repair and the sharded engines all
# iterate the registry, so no repair code needs editing.
# ---------------------------------------------------------------------------

STAT_COUNTERS: dict[str, str] = {}


def register_counter(name: str, doc: str = "") -> None:
    """Add a monotone scalar int32 stat counter to every engine's state."""
    if name in STAT_COUNTERS:
        raise ValueError(f"duplicate stat counter {name!r}")
    STAT_COUNTERS[name] = doc


for _name, _doc in (
    ("delivered", "packets ejected into a PE's local memory"),
    ("noc_deflections", "route-contention deflections: in-flight S-turn "
     "losers plus blocked PE injections (away from the destination)"),
    ("eject_deflections", "eject-port losers at the destination router, "
     "sent around the ring again"),
    ("deflections", "noc_deflections + eject_deflections (back-compat sum)"),
    ("busy_cycles", "node fires summed over PEs and cycles"),
):
    register_counter(_name, _doc)


def stat_keys(state: dict) -> tuple[str, ...]:
    """Registered counters present in ``state``, in registration order —
    the keys the chunk repair stacks into its one-collective stat block."""
    return tuple(k for k in STAT_COUNTERS if k in state)


def init_state(g: DeviceGraph, cfg: OverlayConfig,
               scheduler: schedulers.Scheduler | None = None):
    """Policy-agnostic simulation state. Scheduler state is namespaced under
    ``state["sched"]``; the exposed select latency rides along as the
    ``state["sel_lat"]`` scalar so the batched engine can vmap over it."""
    sched = _resolve(cfg, scheduler)
    nx, ny, L = g["opcode"].shape
    is_input = (g["fanin"] == 0) & g["valid"]
    computed = is_input
    value = jnp.where(is_input, g["init_value"], 0.0)
    lat = sched.sel_lat(cfg, L // bitvec.FLAGS_PER_WORD)

    state = dict(
        pending=g["fanin"].astype(jnp.int32),
        operands=jnp.zeros((nx, ny, L, 2), jnp.float32),
        computed=computed,
        value=value,
        remaining=(g["valid"] & ~computed).sum().astype(jnp.int32),
        sched=sched.init(g, cfg),
        active=jnp.full((nx, ny), -1, jnp.int32),
        cursor=jnp.zeros((nx, ny), jnp.int32),
        cursor_end=jnp.zeros((nx, ny), jnp.int32),
        sel_lat=jnp.int32(lat),
        sel_wait=jnp.full((nx, ny), lat - 1, jnp.int32),
        link_e=noc.empty_packets(nx, ny),
        link_s=noc.empty_packets(nx, ny),
        cycle=jnp.int32(0),
        done=jnp.bool_(False),
        **{k: jnp.int32(0) for k in STAT_COUNTERS},
    )
    if cfg.telemetry is not None:
        from ..telemetry import trace as telemetry_trace  # lazy, like place

        state["telem"] = telemetry_trace.init(cfg.telemetry, nx, ny)
    return state


def make_cycle_fn(
    g: DeviceGraph,
    cfg: OverlayConfig,
    *,
    scheduler: schedulers.Scheduler | None = None,
    shift_e: Shift = noc.roll_shift_e,
    shift_s: Shift = noc.roll_shift_s,
    all_reduce: Callable[[Any], Any] = lambda x: x,
    x0=0,
    y0=0,
    global_ny: int | None = None,
):
    """Build the one-cycle transition function. ``all_reduce`` reduces scalar
    termination predicates across shards (identity on a single device);
    ``x0``/``y0``/``global_ny`` supply global router coordinates when the PE
    grid is sharded (see core.distributed)."""
    sched = _resolve(cfg, scheduler)
    nx, ny, L = g["opcode"].shape
    ny_i32 = jnp.int32(global_ny if global_ny is not None else ny)
    telem_spec = cfg.telemetry
    if telem_spec is not None:
        from ..telemetry import trace as telemetry_trace  # lazy, like place

    def cycle(s):
        # ---- 1. offer injection packet from the active node's fanout cursor
        inj_valid = (s["active"] >= 0) & (s["cursor"] < s["cursor_end"])
        dst_pe = _row_gather(g["e_dst_pe"], s["cursor"])
        inject = dict(
            valid=inj_valid,
            dst_x=dst_pe // ny_i32,
            dst_y=dst_pe % ny_i32,
            dst_slot=_row_gather(g["e_dst_slot"], s["cursor"]),
            opidx=_row_gather(g["e_dst_opidx"], s["cursor"]),
            value=_row_gather(s["value"], s["active"]),
        )

        # ---- 2. NoC cycle
        link_e, link_s, ejects, accepted, deflected = noc.router_cycle(
            s["link_e"], s["link_s"], inject, shift_e=shift_e, shift_s=shift_s,
            x0=x0, y0=y0, eject_capacity=cfg.eject_capacity,
            eject_policy=cfg.eject_policy,
        )

        # ---- 3. advance fanout cursor; retire drained nodes
        cursor = s["cursor"] + accepted.astype(jnp.int32)
        cursor_end = s["cursor_end"]
        drained = (s["active"] >= 0) & (cursor >= cursor_end)
        active = jnp.where(drained, -1, s["active"])
        sel_wait = jnp.where(drained, s["sel_lat"] - 1, s["sel_wait"])

        # ---- 4. apply ejected packets, fused across the eject ports.
        # All eject ports apply as ONE stacked [E, nx, ny] scatter per array
        # instead of ``eject_capacity`` sequential full-grid gather/scatter
        # rounds, and fire detection stays in gathered [E, nx, ny] form, so
        # the per-cycle cost is O(PEs), not O(PEs x slots). Order-free
        # exactness relies on the graph-memory invariants that each
        # (pe, slot, opidx) operand cell receives exactly one packet over the
        # whole run (fanin semantics) and each slot fires at most once, so
        # scatter-*add* into the zero-initialized cells equals the
        # sequential writes of the per-port loop this replaces.
        ix = jnp.arange(nx)[:, None] * jnp.ones((1, ny), jnp.int32)
        iy = jnp.arange(ny)[None, :] * jnp.ones((nx, 1), jnp.int32)
        sched_st = s["sched"]

        ej_valid = jnp.stack([e["valid"] for e in ejects])          # [E,nx,ny]
        ej_slot = jnp.clip(jnp.stack([e["dst_slot"] for e in ejects]), 0, L - 1)
        ej_op = jnp.clip(jnp.stack([e["opidx"] for e in ejects]), 0, 1)
        ej_val = jnp.stack([e["value"] for e in ejects])

        # With one eject port the (pe, slot) scatter indices are unique and
        # iterate in row-major order — tell XLA so it takes the fast path.
        E = len(ejects)
        hints = dict(mode="promise_in_bounds",
                     unique_indices=E == 1, indices_are_sorted=E == 1)
        operands = s["operands"].at[ix[None], iy[None], ej_slot, ej_op].add(
            jnp.where(ej_valid, ej_val, 0.0), **hints)
        pending = s["pending"].at[ix[None], iy[None], ej_slot].add(
            -ej_valid.astype(jnp.int32), **hints)

        # A slot fires the cycle a delivery drops its pending count to zero.
        # Gathered at each port's own target slot; when two ports hit the
        # same slot in one cycle both see the post-decrement count, so the
        # first port claims the fire (same single fire, same operands and
        # value, as the sequential loop).
        new_pend = jnp.stack([_row_gather(pending, ej_slot[e])
                              for e in range(E)])
        was_done = jnp.stack([_row_gather(s["computed"], ej_slot[e])
                              for e in range(E)])
        fired = ej_valid & (new_pend == 0) & ~was_done
        for e in range(1, E):
            for prev in range(e):
                dup = fired[prev] & (ej_slot[prev] == ej_slot[e])
                fired = fired.at[e].set(fired[e] & ~dup)

        opnds = jnp.stack([_row_gather(operands, ej_slot[e])
                           for e in range(E)])                 # [E,nx,ny,2]
        opc = jnp.stack([_row_gather(g["opcode"], ej_slot[e])
                         for e in range(E)])
        fval = alu(opc, opnds[..., 0], opnds[..., 1])
        value = s["value"].at[ix[None], iy[None], ej_slot].add(
            jnp.where(fired, fval, 0.0), **hints)
        computed = s["computed"].at[ix[None], iy[None], ej_slot].max(
            fired, mode="promise_in_bounds")

        # Enqueue fired nodes in eject-port order (per-PE FIFO arrival
        # semantics are exactly the sequential loop's).
        for e in range(E):
            ready_e = fired[e] & (_row_gather(g["fo_count"], ej_slot[e]) > 0)
            sched_st = sched.on_ready(sched_st, ix, iy, ej_slot[e], ready_e)

        n_delivered = ej_valid.sum().astype(jnp.int32)
        n_fired = fired.sum().astype(jnp.int32)

        # ---- 5. scheduler: select (and consume) the next node on idle PEs
        idle = active < 0
        gate = idle & (sel_wait == 0)
        if telem_spec is not None and telem_spec.sched:
            # Ready-set depth as the scheduler sees it at pick time: after
            # this cycle's fires enqueued, before the pick consumes.
            rdy_depth = sched.ready_depth(sched_st)
        cand, have, sched_st = sched.step(sched_st, idle, gate,
                                          use_pallas=cfg.engine == "select")
        can_wait = idle & have & (sel_wait > 0)
        sel_wait = jnp.where(can_wait, sel_wait - 1, sel_wait)
        sel = gate & have

        active = jnp.where(sel, cand, active)
        new_base = _row_gather(g["fo_base"], jnp.clip(cand, 0, L - 1))
        new_cnt = _row_gather(g["fo_count"], jnp.clip(cand, 0, L - 1))
        cursor = jnp.where(sel, new_base, cursor)
        cursor_end = jnp.where(sel, new_base + new_cnt, cursor_end)

        # ---- 6. termination + stats. ``remaining`` counts local uncomputed
        # valid nodes so the all-computed predicate is O(1) per cycle instead
        # of an O(slots) reduction over the computed plane.
        remaining = s["remaining"] - n_fired
        all_computed = all_reduce(remaining == 0)
        no_ready = all_reduce(sched.empty(sched_st))
        no_active = all_reduce((active < 0).all())
        links_idle = all_reduce(noc.links_empty(link_e, link_s))
        done = all_computed & no_ready & no_active & links_idle

        # Deflections, split by cause (see noc.router_cycle): a blocked PE
        # injection keeps the packet circulating in the PE just as a lost
        # S-turn keeps it circulating on the ring, so both count as NoC
        # (route-contention) deflections; eject-port losers count separately.
        # ``deflections`` stays their sum — bit-exactly the pre-split stat.
        inj_blocked = inj_valid & ~accepted
        d_noc = all_reduce(
            inj_blocked.sum() + deflected["noc"].sum()).astype(jnp.int32)
        d_ej = all_reduce(deflected["eject"].sum()).astype(jnp.int32)

        out = dict(
            pending=pending, operands=operands, computed=computed, value=value,
            remaining=remaining,
            sched=sched_st,
            active=active, cursor=cursor, cursor_end=cursor_end,
            sel_lat=s["sel_lat"], sel_wait=sel_wait,
            link_e=link_e, link_s=link_s,
            cycle=s["cycle"] + 1,
            delivered=s["delivered"] + all_reduce(n_delivered).astype(jnp.int32),
            noc_deflections=s["noc_deflections"] + d_noc,
            eject_deflections=s["eject_deflections"] + d_ej,
            deflections=s["deflections"] + d_noc + d_ej,
            busy_cycles=s["busy_cycles"] + all_reduce(n_fired).astype(jnp.int32),
            done=done,
        )
        if telem_spec is not None:
            # Observer only: every input below is shard-local and already
            # computed by the model above; nothing feeds back into it.
            out["telem"] = telemetry_trace.accumulate(
                telem_spec, s["telem"],
                cycle=s["cycle"],
                fired=fired.sum(axis=0).astype(jnp.int32),
                occupied=(s["active"] >= 0),
                link_e_busy=link_e["valid"],
                link_s_busy=link_s["valid"],
                defl_noc=deflected["noc"] + inj_blocked.astype(jnp.int32),
                defl_eject=deflected["eject"],
                eject_grant=ej_valid.sum(axis=0).astype(jnp.int32),
                ready_depth=rdy_depth if telem_spec.sched else None,
                sel=sel, cand=cand,
                no_ready=idle & ~have,
                inj_blocked=inj_blocked,
                sel_waiting=can_wait,
            )
        return out

    return cycle


def repair_telemetry(telem: dict, overshoot):
    """Undo the only telemetry increment that is NOT zero at the completed-
    overlay fixed point: once every PE is idle with an empty ready set,
    ``stall_no_ready`` gains 1 per PE per over-simulated cycle inside a
    guard-free chunk. ``overshoot`` is the chunk's over-simulated cycle count
    (``end_cycle - repaired_cycle``: 0 while running, K - first - 1 when the
    run completes in-chunk, K for an already-done element re-entering). Every
    other trace leaf's increment vanishes at the fixed point (no fires, empty
    links, no packets, empty ready sets, no picks), so chunk overshoot never
    touches it — asserted against check_every=1 in tests/test_telemetry.py.
    """
    if "stall_no_ready" not in telem:
        return telem
    out = dict(telem)
    over = jnp.asarray(overshoot, jnp.int32)
    out["stall_no_ready"] = telem["stall_no_ready"] - over.reshape(
        over.shape + (1, 1))
    return out


def make_chunk_fn(cycle_fn, check_every: int,
                  all_reduce: Callable[[Any], Any] = lambda x: x):
    """Wrap ``check_every`` cycles of ``cycle_fn`` into one chunk step.

    ``cycle_fn`` must be built with the *identity* all_reduce: inside the
    chunk every termination predicate and stat increment stays shard-local,
    and the cross-shard reduction (``all_reduce``) runs once per chunk — on
    the stacked per-cycle done trace and on the chunk's stat deltas — instead
    of ~7 collectives per cycle.

    The chunk body is deliberately guard-free (no per-cycle freeze, no
    branch): a completed overlay is a *fixed point* of ``cycle_fn`` (no
    ready nodes, no active fanout drains, empty links), so cycles simulated
    past completion change nothing but the cycle counter, and the counter is
    repaired afterwards from the first globally-done entry of the per-cycle
    trace. The ``max_cycles`` budget is enforced by the *caller*: only enter
    a chunk when every still-running element has at least ``check_every``
    cycles of budget left, and finish the tail with the per-cycle engine
    (see ``_run_jit``). That keeps the hot path exactly ``check_every``
    back-to-back cycle evaluations.
    """

    def chunk(s):
        keys = stat_keys(s)
        start_stats = jnp.stack([s[k] for k in keys])
        start_cycle = s["cycle"]
        start_done = s["done"]  # already-finished batch elements re-enter

        def body(c, _):
            c = cycle_fn(c)
            return c, c["done"]

        s2, done_trace = jax.lax.scan(body, s, None, length=check_every)

        done_trace = all_reduce(done_trace)            # one collective
        any_done = done_trace.any()
        first = jnp.argmax(done_trace).astype(jnp.int32)
        cycle = jnp.where(
            start_done, start_cycle,
            jnp.where(any_done, start_cycle + first + 1, s2["cycle"]))

        end_stats = jnp.stack([s2[k] for k in keys])
        stats = start_stats + all_reduce(end_stats - start_stats)

        out = dict(s2, done=any_done, cycle=cycle)
        for i, k in enumerate(keys):
            out[k] = stats[i]
        if "telem" in out:
            out["telem"] = repair_telemetry(out["telem"], s2["cycle"] - cycle)
        return out

    return chunk


def make_engine_chunk_fn(g: DeviceGraph, cfg: OverlayConfig, check_every: int,
                         *, scheduler: schedulers.Scheduler | None = None,
                         batched: bool = False,
                         all_reduce: Callable[[Any], Any] = lambda x: x,
                         cycle_fn=None):
    """Chunk step for ``cfg.engine`` — the dispatch point every execution
    engine routes through. ``"megakernel"`` builds the fused single-
    ``pallas_call`` chunk (:mod:`repro.kernels.megakernel`); ``"jnp"`` and
    ``"select"`` scan ``cycle_fn`` (built here when not supplied). With
    ``batched=True`` the returned chunk operates on a stacked config axis
    (the jnp path vmaps; the megakernel vmaps its in-kernel cycle body)."""
    if cfg.engine == "megakernel":
        from ..kernels import megakernel  # lazy: kernels layer is optional

        return megakernel.make_mega_chunk_fn(
            g, cfg, check_every, scheduler=scheduler, batched=batched,
            all_reduce=all_reduce)
    if cycle_fn is None:
        cycle_fn = make_cycle_fn(g, cfg, scheduler=scheduler)
    chunk = make_chunk_fn(cycle_fn, check_every, all_reduce)
    return jax.vmap(chunk) if batched else chunk


@dataclasses.dataclass
class SimResult:
    cycles: int
    done: bool
    values: np.ndarray        # [N] node values in global id order
    delivered: int
    deflections: int          # noc_deflections + eject_deflections
    busy_cycles: int
    noc_deflections: int = 0
    eject_deflections: int = 0
    #: repro.telemetry.TelemetryResult when the config carried a
    #: TelemetrySpec, else None.
    telemetry: Any = None


@functools.partial(jax.jit, static_argnames=("cfg", "nx", "ny"))
def _run_jit(g: dict, cfg: OverlayConfig, nx: int, ny: int):
    state = init_state(g, cfg)
    cycle_fn = make_cycle_fn(g, cfg)
    K = resolve_check_every(cfg, nx, ny, g["opcode"].shape[2])

    def cond(s):
        return (~s["done"]) & (s["cycle"] < cfg.max_cycles)

    if K > 1 or cfg.engine == "megakernel":
        # Chunked phase: K back-to-back cycles per termination check, entered
        # only while a full chunk fits the budget (so no freeze guard is
        # needed inside); the per-cycle loop below finishes the < K tail.
        # The megakernel engine chunks even at K=1 so a check_every=1 run
        # still exercises (and is bit-pinned against) the fused kernel.
        chunk = make_engine_chunk_fn(g, cfg, K, cycle_fn=cycle_fn)
        state = jax.lax.while_loop(
            lambda s: (~s["done"]) & (s["cycle"] + K <= cfg.max_cycles),
            chunk, state)
    final = jax.lax.while_loop(cond, cycle_fn, state)
    return final


def _unpack_result(final, gm: GraphMemory, b: int | None = None,
                   cfg: OverlayConfig | None = None) -> SimResult:
    pick = (lambda a: a[b]) if b is not None else (lambda a: a)
    value = np.asarray(pick(final["value"])).reshape(gm.num_pes, gm.lmax)
    telemetry = None
    if "telem" in final and cfg is not None and cfg.telemetry is not None:
        from ..telemetry.result import TelemetryResult  # lazy, like place

        telemetry = TelemetryResult(
            spec=cfg.telemetry,
            traces={k: np.asarray(pick(v))
                    for k, v in final["telem"].items()},
            cycles=int(pick(final["cycle"])),
            nx=gm.nx, ny=gm.ny,
        )
    return SimResult(
        cycles=int(pick(final["cycle"])),
        done=bool(pick(final["done"])),
        values=value[gm.node_pe, gm.node_slot],
        delivered=int(pick(final["delivered"])),
        deflections=int(pick(final["deflections"])),
        busy_cycles=int(pick(final["busy_cycles"])),
        noc_deflections=int(pick(final["noc_deflections"])),
        eject_deflections=int(pick(final["eject_deflections"])),
        telemetry=telemetry,
    )


def _as_memory(gm, cfg: OverlayConfig, nx: int | None, ny: int | None):
    """Accept a packed GraphMemory or a raw DataflowGraph (+ grid shape).

    A raw graph is placed according to ``cfg.placement`` (identity default)
    with the memory layout the scheduler prefers — the placement subsystem's
    integration point into every engine."""
    if isinstance(gm, GraphMemory):
        return gm
    if isinstance(gm, DataflowGraph):
        if nx is None or ny is None:
            raise ValueError(
                "simulating a raw DataflowGraph needs the PE grid: "
                "pass nx= and ny=")
        from ..place.api import graph_memory_for_config

        return graph_memory_for_config(gm, nx, ny, cfg)
    raise TypeError(f"expected GraphMemory or DataflowGraph, got {type(gm)}")


def _simulate(gm: GraphMemory | DataflowGraph,
              cfg: OverlayConfig | None = None,
              *, nx: int | None = None, ny: int | None = None) -> SimResult:
    """Run the overlay to completion on a single device.

    Accepts a packed :class:`GraphMemory`, or a raw
    :class:`~repro.core.graph.DataflowGraph` plus ``nx``/``ny`` — the graph
    is then placed per ``cfg.placement`` (see :mod:`repro.place`).

    Internal engine behind :func:`repro.run`; the public entry point is the
    dispatcher, not this function.
    """
    cfg = cfg or OverlayConfig()
    gm = _as_memory(gm, cfg, nx, ny)
    g = device_graph(gm)
    final = _run_jit(dict(g), cfg, gm.nx, gm.ny)
    return _unpack_result(final, gm, cfg=cfg)


def simulate(gm: GraphMemory | DataflowGraph, cfg: OverlayConfig | None = None,
             *, nx: int | None = None, ny: int | None = None) -> SimResult:
    """DEPRECATED: use :func:`repro.run` (same arguments, same result)."""
    warnings.warn(
        "overlay.simulate is deprecated; use repro.run(gm, cfg, nx=, ny=)",
        DeprecationWarning, stacklevel=2)
    return _simulate(gm, cfg, nx=nx, ny=ny)


# ---------------------------------------------------------------------------
# Batched sweep engine: one XLA program for an entire config sweep.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "names", "nx", "ny"))
def _run_batch_jit(g: dict, cfg: OverlayConfig, names: tuple[str, ...],
                   policy_ids, sel_lats, max_cycs, nx: int, ny: int):
    sched = schedulers.BatchedScheduler(names)

    def init_one(pid, lat):
        s = init_state(g, cfg, scheduler=sched)
        s["sched"]["policy_id"] = pid
        s["sel_lat"] = lat
        s["sel_wait"] = jnp.full_like(s["sel_wait"], lat - 1)
        return s

    state = jax.vmap(init_one)(policy_ids, sel_lats)
    cycle_fn = make_cycle_fn(g, cfg, scheduler=sched)
    nx_, ny_, L = g["opcode"].shape
    K = resolve_check_every(cfg, nx_, ny_, L)
    vcycle = jax.vmap(cycle_fn)

    def cond(s):
        return ((~s["done"]) & (s["cycle"] < max_cycs)).any()

    def freeze_body(s):
        new = vcycle(s)
        halted = s["done"] | (s["cycle"] >= max_cycs)

        def freeze(old, upd):
            d = halted.reshape(halted.shape + (1,) * (old.ndim - 1))
            return jnp.where(d, old, upd)

        # Batch elements that finished (or exhausted their own cycle budget)
        # stop evolving, so each element's final cycle count and done flag
        # are exactly what a solo run with the same config would report.
        return jax.tree.map(freeze, s, new)

    if K > 1 or cfg.engine == "megakernel":
        # Chunked phase, vmapped whole: guard-free K-cycle chunks run while
        # every still-running element has a full chunk of budget left
        # (completed elements are fixed points and get their cycle counter
        # repaired from their own done trace — see make_chunk_fn); the
        # per-cycle freeze body then finishes the heterogeneous tail.
        vchunk = make_engine_chunk_fn(g, cfg, K, scheduler=sched,
                                      batched=True, cycle_fn=cycle_fn)

        def chunk_cond(s):
            running = (~s["done"]) & (s["cycle"] < max_cycs)
            # Any unfinished element without a full chunk of budget left —
            # including one already AT its budget, which is not a fixed
            # point — must force the exit to the freezing per-cycle tail.
            overruns = (~s["done"]) & (s["cycle"] + K > max_cycs)
            return running.any() & ~overruns.any()

        state = jax.lax.while_loop(chunk_cond, vchunk, state)

    return jax.lax.while_loop(cond, freeze_body, state)


def _simulate_batch(gm: GraphMemory | DataflowGraph,
                    cfgs: Sequence[OverlayConfig], *,
                    nx: int | None = None,
                    ny: int | None = None) -> list[SimResult]:
    """Run one overlay graph under many configs as a single XLA program.

    The cycle body is vmapped over a stacked config axis (policy id, exposed
    select latency, cycle budget), so a Fig. 1-style N-scheduler x M-latency
    sweep compiles once instead of retracing per config. Batch elements that
    finish — or exhaust their own ``max_cycles`` — freeze in place, so every
    returned result is identical to a serial :func:`simulate` call with the
    same config. Requirements: all configs share ``eject_capacity``,
    ``eject_policy``, ``engine``, ``placement`` and ``telemetry`` (they
    change the traced structure / the packed memory image).

    A raw :class:`~repro.core.graph.DataflowGraph` (plus ``nx``/``ny``) is
    placed per the shared ``placement`` before the sweep.
    """
    cfgs = list(cfgs)
    if not cfgs:
        return []
    eject = {c.eject_capacity for c in cfgs}
    if len(eject) != 1:
        raise ValueError(f"simulate_batch needs a uniform eject_capacity, got {eject}")
    policy = {c.eject_policy for c in cfgs}
    if len(policy) != 1:
        raise ValueError(f"simulate_batch needs a uniform eject_policy, got {policy}")
    engines = {c.engine for c in cfgs}
    if len(engines) != 1:
        raise ValueError(
            f"simulate_batch needs a uniform engine "
            f"('jnp' | 'select' | 'megakernel'), got {engines}")
    placements = {c.placement for c in cfgs}
    if len(placements) != 1:
        raise ValueError(
            f"simulate_batch needs a uniform placement, got {placements}")
    telems = {c.telemetry for c in cfgs}
    if len(telems) != 1:
        raise ValueError(
            f"simulate_batch needs a uniform telemetry spec (it shapes the "
            f"traced state), got {telems}")
    if not isinstance(gm, GraphMemory):
        # The packed memory image is shared across the batch, so every
        # scheduler must want the same slot layout — otherwise elements would
        # silently diverge from their serial runs. Group configs by layout
        # (as benchmarks/fig1 does) or pass a pre-built GraphMemory.
        wants = {schedulers.get(c.scheduler).wants_criticality_order
                 for c in cfgs}
        if len(wants) != 1:
            raise ValueError(
                "simulate_batch over a raw DataflowGraph needs schedulers "
                "with a uniform wants_criticality_order; group configs by "
                "memory layout or pass a pre-built GraphMemory")
    gm = _as_memory(gm, cfgs[0], nx, ny)
    names: list[str] = []
    for c in cfgs:
        schedulers.get(c.scheduler)  # validate early
        if c.scheduler not in names:
            names.append(c.scheduler)

    base = dataclasses.replace(
        cfgs[0], scheduler=names[0], select_latency=None,
        max_cycles=max(c.max_cycles for c in cfgs))
    g = device_graph(gm)
    num_words = g["opcode"].shape[2] // bitvec.FLAGS_PER_WORD
    policy_ids = jnp.asarray([names.index(c.scheduler) for c in cfgs], jnp.int32)
    sel_lats = jnp.asarray(
        [schedulers.get(c.scheduler).sel_lat(c, num_words) for c in cfgs],
        jnp.int32)
    max_cycs = jnp.asarray([c.max_cycles for c in cfgs], jnp.int32)

    final = _run_batch_jit(dict(g), base, tuple(names), policy_ids, sel_lats,
                           max_cycs, gm.nx, gm.ny)
    return [_unpack_result(final, gm, b, cfg=base) for b in range(len(cfgs))]


def simulate_batch(gm: GraphMemory | DataflowGraph,
                   cfgs: Sequence[OverlayConfig], *,
                   nx: int | None = None,
                   ny: int | None = None) -> list[SimResult]:
    """DEPRECATED: use :func:`repro.run` with ``batch=cfgs``."""
    warnings.warn(
        "overlay.simulate_batch is deprecated; use "
        "repro.run(gm, batch=cfgs, nx=, ny=)",
        DeprecationWarning, stacklevel=2)
    return _simulate_batch(gm, cfgs, nx=nx, ny=ny)
