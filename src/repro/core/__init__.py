# The paper's primary contribution: a token-dataflow overlay with out-of-order
# ready-node scheduling (packed RDY bit-flags + hierarchical leading-one
# detect + static criticality-ordered local memory), a cycle-accurate Hoplite
# NoC model, and shard_map distribution mapping the overlay torus onto ICI.
from .graph import (  # noqa: F401
    OP_ADD, OP_DIV, OP_INPUT, OP_MUL, OP_SUB,
    DataflowGraph, GraphBuilder, reference_evaluate,
)
from .criticality import criticality  # noqa: F401
from .partition import GraphMemory, build_graph_memory  # noqa: F401
from .overlay import OverlayConfig, SimResult, simulate, simulate_batch  # noqa: F401
from .schedulers import REGISTRY as SCHEDULER_REGISTRY  # noqa: F401
