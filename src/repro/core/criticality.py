"""Static one-time criticality labeling (paper §II-B).

The paper runs "a one-time software criticality evaluation on the application
dataflow graph" and stores nodes in each PE's local memory in *decreasing*
criticality order, so that the hierarchical leading-one detector implicitly
picks the most critical ready node.

We provide three metrics:
  * ``height``  — longest path (in nodes) from the node to any sink. This is
    the classic critical-path criticality: nodes on the critical path have
    maximal height at their depth. (default; what the paper's heuristic needs)
  * ``slack``   — ALAP(v) - ASAP(v); criticality = -slack (0-slack nodes are
    on the critical path).
  * ``fanout_height`` — height weighted by downstream fanout mass, a tiebreak
    that prefers nodes unlocking more parallelism.
"""
from __future__ import annotations

import numpy as np

from .graph import DataflowGraph


def asap_levels(g: DataflowGraph) -> np.ndarray:
    """[N] earliest firing level (INPUTs at 0)."""
    order = g.topological_order()
    lvl = np.zeros(g.num_nodes, dtype=np.int64)
    ptr, dst = g.fanout_ptr, g.fanout_dst
    for v in order:
        for u in dst[ptr[v]:ptr[v + 1]]:
            lvl[u] = max(lvl[u], lvl[v] + 1)
    return lvl


def height(g: DataflowGraph) -> np.ndarray:
    """[N] longest path to a sink, in edges (sinks have height 0)."""
    order = g.topological_order()
    h = np.zeros(g.num_nodes, dtype=np.int64)
    ptr, dst = g.fanout_ptr, g.fanout_dst
    for v in order[::-1]:
        lo, hi = ptr[v], ptr[v + 1]
        if hi > lo:
            h[v] = 1 + h[dst[lo:hi]].max()
    return h


def slack(g: DataflowGraph) -> np.ndarray:
    """[N] ALAP - ASAP. Zero slack == critical path."""
    asap = asap_levels(g)
    h = height(g)
    depth = int((asap + h).max()) if g.num_nodes else 0
    alap = depth - h
    return alap - asap


def fanout_height(g: DataflowGraph) -> np.ndarray:
    """Height with a fractional fanout tiebreak in [0, 1)."""
    h = height(g).astype(np.float64)
    fo = g.fanout_count().astype(np.float64)
    return h + fo / (fo.max() + 1.0)


_METRICS = {
    "height": height,
    "neg_slack": lambda g: -slack(g),
    "fanout_height": fanout_height,
}


def criticality(g: DataflowGraph, metric: str = "height") -> np.ndarray:
    """[N] criticality labels; larger == more critical."""
    try:
        fn = _METRICS[metric]
    except KeyError:
        raise ValueError(f"unknown criticality metric {metric!r}; have {sorted(_METRICS)}")
    return np.asarray(fn(g))
