"""Dataflow graph IR for the token-dataflow overlay.

A graph is a DAG of binary floating-point operators (the paper's workloads are
dataflow graphs extracted from sparse matrix factorization kernels). Nodes obey
the dataflow firing rule: a node executes once all of its operands have
arrived. INPUT nodes carry initial token values and fire at cycle 0.

The IR is plain numpy (static, host-side); the overlay simulator consumes a
packed per-PE view built by :mod:`repro.core.partition`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Opcodes. All non-INPUT ops are binary (fanin == 2).
OP_INPUT = 0
OP_ADD = 1
OP_SUB = 2
OP_MUL = 3
OP_DIV = 4  # "safe" divide: a / (b + eps*sign(b)) — identical in ref and sim.

OP_NAMES = {OP_INPUT: "input", OP_ADD: "add", OP_SUB: "sub", OP_MUL: "mul", OP_DIV: "div"}
DIV_EPS = 1e-3


@dataclasses.dataclass(frozen=True)
class DataflowGraph:
    """CSR-encoded dataflow DAG.

    Attributes:
      opcode: [N] int8 opcodes.
      fanout_ptr: [N+1] int64 CSR row pointers into fanout arrays.
      fanout_dst: [E] int64 destination node id per edge.
      fanout_slot: [E] int8 operand slot (0 or 1) at the destination.
      initial_values: [N] float32; defined only where opcode == OP_INPUT.
    """

    opcode: np.ndarray
    fanout_ptr: np.ndarray
    fanout_dst: np.ndarray
    fanout_slot: np.ndarray
    initial_values: np.ndarray

    @property
    def num_nodes(self) -> int:
        return int(self.opcode.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.fanout_dst.shape[0])

    def fanin_count(self) -> np.ndarray:
        """[N] number of operands each node waits for (0 for INPUT, 2 else)."""
        return np.where(self.opcode == OP_INPUT, 0, 2).astype(np.int32)

    def fanout_count(self) -> np.ndarray:
        return (self.fanout_ptr[1:] - self.fanout_ptr[:-1]).astype(np.int32)

    def validate(self) -> None:
        n, e = self.num_nodes, self.num_edges
        assert self.fanout_ptr.shape == (n + 1,)
        assert self.fanout_ptr[0] == 0 and self.fanout_ptr[-1] == e
        assert (np.diff(self.fanout_ptr) >= 0).all()
        assert self.fanout_dst.min(initial=0) >= 0
        assert self.fanout_dst.max(initial=-1) < n
        assert set(np.unique(self.fanout_slot)) <= {0, 1}
        # Every non-input node receives exactly one edge per operand slot.
        recv = np.zeros((n, 2), dtype=np.int64)
        np.add.at(recv, (self.fanout_dst, self.fanout_slot.astype(np.int64)), 1)
        non_input = self.opcode != OP_INPUT
        if not (recv[non_input] == 1).all():
            bad = np.where(non_input & ~(recv == 1).all(axis=1))[0][:8]
            raise ValueError(f"nodes with missing/duplicate operands: {bad}")
        if not (recv[~non_input] == 0).all():
            raise ValueError("INPUT nodes must not receive edges")
        self.topological_order()  # raises on cycles

    def topological_order(self) -> np.ndarray:
        """Kahn topological order; raises ValueError on cycles."""
        n = self.num_nodes
        indeg = np.zeros(n, dtype=np.int64)
        np.add.at(indeg, self.fanout_dst, 1)
        order = np.empty(n, dtype=np.int64)
        frontier = list(np.where(indeg == 0)[0])
        k = 0
        ptr, dst = self.fanout_ptr, self.fanout_dst
        while frontier:
            v = frontier.pop()
            order[k] = v
            k += 1
            for u in dst[ptr[v]:ptr[v + 1]]:
                indeg[u] -= 1
                if indeg[u] == 0:
                    frontier.append(int(u))
        if k != n:
            raise ValueError("graph has a cycle")
        return order


def apply_op(opcode: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Vectorized ALU semantics shared by the reference evaluator and the sim."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    safe_b = b + np.where(b >= 0, DIV_EPS, -DIV_EPS).astype(np.float32)
    out = np.select(
        [opcode == OP_ADD, opcode == OP_SUB, opcode == OP_MUL, opcode == OP_DIV],
        [a + b, a - b, a * b, a / safe_b],
        default=np.float32(0),
    )
    return out.astype(np.float32)


def reference_evaluate(g: DataflowGraph) -> np.ndarray:
    """Functional oracle: evaluate the DAG in topological order. [N] float32."""
    order = g.topological_order()
    value = np.zeros(g.num_nodes, dtype=np.float32)
    operands = np.zeros((g.num_nodes, 2), dtype=np.float32)
    is_input = g.opcode == OP_INPUT
    value[is_input] = g.initial_values[is_input]
    ptr, dst, slot = g.fanout_ptr, g.fanout_dst, g.fanout_slot
    for v in order:
        if not is_input[v]:
            value[v] = apply_op(g.opcode[v], operands[v, 0], operands[v, 1])
        lo, hi = ptr[v], ptr[v + 1]
        operands[dst[lo:hi], slot[lo:hi].astype(np.int64)] = value[v]
    return value


class GraphBuilder:
    """Convenience builder used by workload generators."""

    def __init__(self) -> None:
        self._op: list[int] = []
        self._init: list[float] = []
        self._edges: list[tuple[int, int, int]] = []  # (src, dst, slot)

    def input(self, value: float) -> int:
        self._op.append(OP_INPUT)
        self._init.append(float(value))
        return len(self._op) - 1

    def op(self, opcode: int, a: int, b: int) -> int:
        assert opcode in (OP_ADD, OP_SUB, OP_MUL, OP_DIV)
        self._op.append(opcode)
        self._init.append(0.0)
        v = len(self._op) - 1
        self._edges.append((a, v, 0))
        self._edges.append((b, v, 1))
        return v

    def build(self, validate: bool = True) -> DataflowGraph:
        n = len(self._op)
        e = len(self._edges)
        src = np.array([s for s, _, _ in self._edges], dtype=np.int64)
        dst = np.array([d for _, d, _ in self._edges], dtype=np.int64)
        slot = np.array([sl for _, _, sl in self._edges], dtype=np.int8)
        order = np.argsort(src, kind="stable")
        src, dst, slot = src[order], dst[order], slot[order]
        ptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(ptr, src + 1, 1)
        ptr = np.cumsum(ptr)
        g = DataflowGraph(
            opcode=np.array(self._op, dtype=np.int8),
            fanout_ptr=ptr,
            fanout_dst=dst,
            fanout_slot=slot,
            initial_values=np.array(self._init, dtype=np.float32),
        )
        if validate:
            g.validate()
        return g
