"""Multi-device overlay execution: the Hoplite torus mapped onto the ICI torus.

The per-PE layout of :mod:`repro.core.overlay` makes every per-cycle update
local to a PE row, so the whole simulator runs under ``shard_map``: the PE
grid [nx, ny] is tiled over the ("data", "model") mesh axes, torus link
shifts become *local roll + ppermute edge exchange* (a collective-permute IS
a NoC hop on the physical ICI torus — the paper's topology maps 1:1), and
the termination predicate is a psum-reduced flag.

This is the production path for overlays larger than one device and the
distribution showcase for the multi-pod dry-run (see tests + dryrun).

Scheduling is delegated to :mod:`repro.core.schedulers` through the same
protocol the single-device engine uses, so every registered policy (``ooo``,
``inorder``, ``scan``, ``lru_flat``, and any future registration) runs under
shard_map with no changes here.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import bitvec, overlay, schedulers
from .partition import GraphMemory

# jax >= 0.6 exposes shard_map at the top level (check_vma kwarg); older
# releases ship it under jax.experimental (check_rep kwarg).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_KW = {"check_rep": False}


def _shard_shift(axis_name: str, axis_idx: int, n: int):
    """Torus shift by +1 along array axis ``axis_idx`` where that axis is
    sharded ``n``-way over mesh axis ``axis_name``: local roll + ppermute of
    the edge slice to the next shard (wrap-around = the torus link). After
    the local roll, local row 0 holds the old local *last* row — exactly the
    edge owed to the next shard; every shard receives its predecessor's."""

    def shift(pkt: dict) -> dict:
        out = {}
        for k, v in pkt.items():
            rolled = jnp.roll(v, 1, axis=axis_idx)
            if n == 1:
                out[k] = rolled
                continue
            edge = jax.lax.slice_in_dim(rolled, 0, 1, axis=axis_idx)
            perm = [(i, (i + 1) % n) for i in range(n)]
            recv = jax.lax.ppermute(edge, axis_name, perm)
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                rolled, recv, 0, axis=axis_idx)
        return out

    return shift


def _mk_chunk(gl, cfg, K, sched, mk_cycle, all_reduce, nsx, nsy, *, batched):
    """Per-shard chunk step honoring ``cfg.engine``.

    The megakernel chunk (one fused ``pallas_call`` per K cycles) cannot
    contain the cross-shard ppermute a sharded torus shift needs, so it only
    engages when both mesh axes are size 1 — the shifts are then pure local
    rolls and the shard-local grid IS the global grid (x0 = y0 = 0). Any
    real multi-shard mesh silently falls back to the jnp chunk, whose
    once-per-chunk psum/pmin already amortizes the collectives
    (docs/megakernel.md, "Fallback semantics")."""
    if cfg.engine == "megakernel" and nsx == 1 and nsy == 1:
        from ..kernels import megakernel

        return megakernel.make_mega_chunk_fn(
            gl, cfg, K, scheduler=sched, batched=batched,
            all_reduce=all_reduce)
    chunk = overlay.make_chunk_fn(mk_cycle(lambda x: x), K, all_reduce)
    return jax.vmap(chunk) if batched else chunk


def _gather_telem(telem: dict, axis_x: str, axis_y: str) -> dict:
    """Reassemble shard-local telemetry traces into the global PE grid.

    Every telemetry leaf keeps its grid dims as the LAST TWO axes (bucketed
    traces are [NB, nx, ny], per-PE totals [nx, ny]; the batched engine adds
    a leading config axis), so one tiled all_gather per mesh axis rebuilds
    the replicated global trace — accumulation is purely PE-local, hence the
    gathered result is bit-identical to a single-device run."""

    def gather(leaf):
        leaf = jax.lax.all_gather(leaf, axis_y, axis=leaf.ndim - 1, tiled=True)
        return jax.lax.all_gather(leaf, axis_x, axis=leaf.ndim - 2, tiled=True)

    return {k: gather(v) for k, v in telem.items()}


def _mk_all_reduce(axis_x: str, axis_y: str):
    def all_reduce(x):
        if x.dtype == jnp.bool_:  # logical AND across shards
            return jax.lax.pmin(
                x.astype(jnp.int32), (axis_x, axis_y)).astype(jnp.bool_)
        return jax.lax.psum(x, (axis_x, axis_y))

    return all_reduce


def _simulate_sharded(gm: GraphMemory, mesh: Mesh,
                      cfg: overlay.OverlayConfig | None = None,
                      axis_x: str = "data", axis_y: str = "model",
                      nx: int | None = None, ny: int | None = None):
    """Run the overlay with the PE grid sharded over ``mesh``.

    nx must divide by mesh.shape[axis_x], ny by mesh.shape[axis_y].
    Returns the same SimResult as overlay.simulate. Accepts a packed
    :class:`GraphMemory` or a raw ``DataflowGraph`` plus ``nx``/``ny`` (the
    graph is then placed per ``cfg.placement`` — see :mod:`repro.place`).

    The stepping is chunked (``cfg.check_every``; the autotune sees the mesh
    size, so multi-device runs default to deep 32-cycle chunks): the cycle
    body inside a chunk keeps every predicate and stat shard-local, and the
    cross-shard psum/pmin runs once per chunk on the stacked done trace
    and the stat deltas — two collectives per ``check_every`` cycles instead
    of ~seven per cycle. ``check_every=1`` is the legacy per-cycle engine.
    """
    cfg = cfg or overlay.OverlayConfig()
    gm = overlay._as_memory(gm, cfg, nx, ny)
    sched = schedulers.get(cfg.scheduler)
    g = overlay.device_graph(gm)
    K = overlay.resolve_check_every(cfg, gm.nx, gm.ny, g["opcode"].shape[2],
                                    num_devices=mesh.size)

    def spec_for(leaf):
        return P(axis_x, axis_y, *([None] * (leaf.ndim - 2)))

    nsx = mesh.shape[axis_x]
    nsy = mesh.shape[axis_y]

    @functools.partial(_shard_map, mesh=mesh,
                       in_specs=(jax.tree.map(spec_for, dict(g)),),
                       out_specs=P(),
                       **_SM_KW)
    def run(gl):
        state = overlay.init_state(gl, cfg, scheduler=sched)
        nx_loc = gl["opcode"].shape[0]
        ny_loc = gl["opcode"].shape[1]
        all_reduce = _mk_all_reduce(axis_x, axis_y)

        def mk_cycle(reduce):
            return overlay.make_cycle_fn(
                gl, cfg,
                scheduler=sched,
                shift_e=_shard_shift(axis_x, 0, nsx),
                shift_s=_shard_shift(axis_y, 1, nsy),
                all_reduce=reduce,
                x0=jax.lax.axis_index(axis_x) * nx_loc,
                y0=jax.lax.axis_index(axis_y) * ny_loc,
                global_ny=gm.ny,
            )

        def cond(s):
            return (~s["done"]) & (s["cycle"] < cfg.max_cycles)

        if K > 1 or cfg.engine == "megakernel":
            # Guard-free chunks while a whole chunk fits the budget; the
            # per-cycle engine (with its per-cycle collectives) only runs
            # the < K tail cycles.
            chunk = _mk_chunk(gl, cfg, K, sched, mk_cycle, all_reduce,
                              nsx, nsy, batched=False)
            state = jax.lax.while_loop(
                lambda s: (~s["done"]) & (s["cycle"] + K <= cfg.max_cycles),
                chunk, state)
        final = jax.lax.while_loop(cond, mk_cycle(all_reduce), state)
        # return per-shard values gathered to replicated full grid
        out = {
            "value": jax.lax.all_gather(final["value"], axis_y, axis=1, tiled=True),
            "cycle": final["cycle"],
            "done": final["done"],
        }
        for k in overlay.stat_keys(final):
            out[k] = final[k]
        out["value"] = jax.lax.all_gather(out["value"], axis_x, axis=0, tiled=True)
        if "telem" in final:
            out["telem"] = _gather_telem(final["telem"], axis_x, axis_y)
        return out

    return overlay._unpack_result(run(dict(g)), gm, cfg=cfg)


def simulate_sharded(gm: GraphMemory, mesh: Mesh,
                     cfg: overlay.OverlayConfig | None = None,
                     axis_x: str = "data", axis_y: str = "model",
                     nx: int | None = None, ny: int | None = None):
    """DEPRECATED: use :func:`repro.run` with ``mesh=mesh``."""
    warnings.warn(
        "distributed.simulate_sharded is deprecated; use "
        "repro.run(gm, cfg, mesh=mesh, nx=, ny=)",
        DeprecationWarning, stacklevel=2)
    return _simulate_sharded(gm, mesh, cfg, axis_x, axis_y, nx, ny)


def _simulate_batch_sharded(gm: GraphMemory, mesh: Mesh,
                            cfgs, axis_x: str = "data", axis_y: str = "model",
                            nx: int | None = None, ny: int | None = None):
    """Multi-config sweep of a sharded overlay: vmap inside shard_map.

    One XLA program runs every config of ``cfgs`` (scheduler / select latency
    / cycle budget may vary; ``eject_capacity``, ``eject_policy``,
    ``engine``, ``placement`` and ``telemetry`` must be uniform) with the
    PE grid
    tiled over ``mesh`` — the batched counterpart
    of :func:`simulate_sharded` for overlays larger than one device, and the
    sharded counterpart of :func:`repro.core.overlay.simulate_batch`. The
    cycle body is vmapped over the stacked config axis; torus ppermutes and
    the once-per-chunk psum/pmin become batched collectives. Results are
    element-wise identical to serial :func:`simulate_sharded` runs.
    """
    cfgs = list(cfgs)
    if not cfgs:
        return []
    eject = {c.eject_capacity for c in cfgs}
    if len(eject) != 1:
        raise ValueError(
            f"simulate_batch_sharded needs a uniform eject_capacity, got {eject}")
    policy = {c.eject_policy for c in cfgs}
    if len(policy) != 1:
        raise ValueError(
            f"simulate_batch_sharded needs a uniform eject_policy, got {policy}")
    engines = {c.engine for c in cfgs}
    if len(engines) != 1:
        raise ValueError(
            f"simulate_batch_sharded needs a uniform engine "
            f"('jnp' | 'select' | 'megakernel'), got {engines}")
    placements = {c.placement for c in cfgs}
    if len(placements) != 1:
        raise ValueError(
            f"simulate_batch_sharded needs a uniform placement, got {placements}")
    telems = {c.telemetry for c in cfgs}
    if len(telems) != 1:
        raise ValueError(
            f"simulate_batch_sharded needs a uniform telemetry spec (it "
            f"shapes the traced state), got {telems}")
    if not isinstance(gm, GraphMemory):
        # Shared packed memory image: see overlay.simulate_batch.
        wants = {schedulers.get(c.scheduler).wants_criticality_order
                 for c in cfgs}
        if len(wants) != 1:
            raise ValueError(
                "simulate_batch_sharded over a raw DataflowGraph needs "
                "schedulers with a uniform wants_criticality_order; group "
                "configs by memory layout or pass a pre-built GraphMemory")
    gm = overlay._as_memory(gm, cfgs[0], nx, ny)
    names: list[str] = []
    for c in cfgs:
        schedulers.get(c.scheduler)  # validate early
        if c.scheduler not in names:
            names.append(c.scheduler)

    base = dataclasses.replace(
        cfgs[0], scheduler=names[0], select_latency=None,
        max_cycles=max(c.max_cycles for c in cfgs))
    sched = schedulers.BatchedScheduler(tuple(names))
    g = overlay.device_graph(gm)
    L = g["opcode"].shape[2]
    num_words = L // bitvec.FLAGS_PER_WORD
    policy_ids = jnp.asarray([names.index(c.scheduler) for c in cfgs], jnp.int32)
    sel_lats = jnp.asarray(
        [schedulers.get(c.scheduler).sel_lat(c, num_words) for c in cfgs],
        jnp.int32)
    max_cycs = jnp.asarray([c.max_cycles for c in cfgs], jnp.int32)
    K = overlay.resolve_check_every(base, gm.nx, gm.ny, L,
                                    num_devices=mesh.size)

    def spec_for(leaf):
        return P(axis_x, axis_y, *([None] * (leaf.ndim - 2)))

    nsx = mesh.shape[axis_x]
    nsy = mesh.shape[axis_y]

    @functools.partial(_shard_map, mesh=mesh,
                       in_specs=(jax.tree.map(spec_for, dict(g)), P(), P(), P()),
                       out_specs=P(),
                       **_SM_KW)
    def run(gl, policy_ids, sel_lats, max_cycs):
        nx_loc = gl["opcode"].shape[0]
        ny_loc = gl["opcode"].shape[1]

        def init_one(pid, lat):
            s = overlay.init_state(gl, base, scheduler=sched)
            s["sched"]["policy_id"] = pid
            s["sel_lat"] = lat
            s["sel_wait"] = jnp.full_like(s["sel_wait"], lat - 1)
            return s

        state = jax.vmap(init_one)(policy_ids, sel_lats)
        all_reduce = _mk_all_reduce(axis_x, axis_y)

        def mk_cycle(reduce):
            return overlay.make_cycle_fn(
                gl, base,
                scheduler=sched,
                shift_e=_shard_shift(axis_x, 0, nsx),
                shift_s=_shard_shift(axis_y, 1, nsy),
                all_reduce=reduce,
                x0=jax.lax.axis_index(axis_x) * nx_loc,
                y0=jax.lax.axis_index(axis_y) * ny_loc,
                global_ny=gm.ny,
            )

        def cond(s):
            return ((~s["done"]) & (s["cycle"] < max_cycs)).any()

        if K > 1 or base.engine == "megakernel":
            vchunk = _mk_chunk(gl, base, K, sched, mk_cycle, all_reduce,
                               nsx, nsy, batched=True)

            def chunk_cond(s):
                running = (~s["done"]) & (s["cycle"] < max_cycs)
                # An unfinished element at/near its budget is not a fixed
                # point — it must force the exit to the per-cycle tail.
                overruns = (~s["done"]) & (s["cycle"] + K > max_cycs)
                return running.any() & ~overruns.any()

            state = jax.lax.while_loop(chunk_cond, vchunk, state)

        vcycle = jax.vmap(mk_cycle(all_reduce))

        def freeze_body(s):
            new = vcycle(s)
            halted = s["done"] | (s["cycle"] >= max_cycs)

            def freeze(old, upd):
                d = halted.reshape(halted.shape + (1,) * (old.ndim - 1))
                return jnp.where(d, old, upd)

            return jax.tree.map(freeze, s, new)

        final = jax.lax.while_loop(cond, freeze_body, state)
        value = jax.lax.all_gather(final["value"], axis_y, axis=2, tiled=True)
        out = {
            "value": jax.lax.all_gather(value, axis_x, axis=1, tiled=True),
            "cycle": final["cycle"],
            "done": final["done"],
        }
        for k in overlay.stat_keys(final):
            out[k] = final[k]
        if "telem" in final:
            out["telem"] = _gather_telem(final["telem"], axis_x, axis_y)
        return out

    final = run(dict(g), policy_ids, sel_lats, max_cycs)
    return [overlay._unpack_result(final, gm, b, cfg=base)
            for b in range(len(cfgs))]


def simulate_batch_sharded(gm: GraphMemory, mesh: Mesh,
                           cfgs, axis_x: str = "data", axis_y: str = "model",
                           nx: int | None = None, ny: int | None = None):
    """DEPRECATED: use :func:`repro.run` with ``mesh=mesh, batch=cfgs``."""
    warnings.warn(
        "distributed.simulate_batch_sharded is deprecated; use "
        "repro.run(gm, mesh=mesh, batch=cfgs, nx=, ny=)",
        DeprecationWarning, stacklevel=2)
    return _simulate_batch_sharded(gm, mesh, cfgs, axis_x, axis_y, nx, ny)
