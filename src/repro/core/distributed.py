"""Multi-device overlay execution: the Hoplite torus mapped onto the ICI torus.

The per-PE layout of :mod:`repro.core.overlay` makes every per-cycle update
local to a PE row, so the whole simulator runs under ``shard_map``: the PE
grid [nx, ny] is tiled over the ("data", "model") mesh axes, torus link
shifts become *local roll + ppermute edge exchange* (a collective-permute IS
a NoC hop on the physical ICI torus — the paper's topology maps 1:1), and
the termination predicate is a psum-reduced flag.

This is the production path for overlays larger than one device and the
distribution showcase for the multi-pod dry-run (see tests + dryrun).

Scheduling is delegated to :mod:`repro.core.schedulers` through the same
protocol the single-device engine uses, so every registered policy (``ooo``,
``inorder``, ``scan``, ``lru_flat``, and any future registration) runs under
shard_map with no changes here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import overlay, schedulers
from .partition import GraphMemory

# jax >= 0.6 exposes shard_map at the top level (check_vma kwarg); older
# releases ship it under jax.experimental (check_rep kwarg).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_KW = {"check_rep": False}


def _shard_shift(axis_name: str, axis_idx: int, n: int):
    """Torus shift by +1 along array axis ``axis_idx`` where that axis is
    sharded ``n``-way over mesh axis ``axis_name``: local roll + ppermute of
    the edge slice to the next shard (wrap-around = the torus link). After
    the local roll, local row 0 holds the old local *last* row — exactly the
    edge owed to the next shard; every shard receives its predecessor's."""

    def shift(pkt: dict) -> dict:
        out = {}
        for k, v in pkt.items():
            rolled = jnp.roll(v, 1, axis=axis_idx)
            if n == 1:
                out[k] = rolled
                continue
            edge = jax.lax.slice_in_dim(rolled, 0, 1, axis=axis_idx)
            perm = [(i, (i + 1) % n) for i in range(n)]
            recv = jax.lax.ppermute(edge, axis_name, perm)
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                rolled, recv, 0, axis=axis_idx)
        return out

    return shift


def simulate_sharded(gm: GraphMemory, mesh: Mesh, cfg: overlay.OverlayConfig | None = None,
                     axis_x: str = "data", axis_y: str = "model"):
    """Run the overlay with the PE grid sharded over ``mesh``.

    nx must divide by mesh.shape[axis_x], ny by mesh.shape[axis_y].
    Returns the same SimResult as overlay.simulate.
    """
    cfg = cfg or overlay.OverlayConfig()
    sched = schedulers.get(cfg.scheduler)
    g = overlay.device_graph(gm)

    def spec_for(leaf):
        return P(axis_x, axis_y, *([None] * (leaf.ndim - 2)))

    nsx = mesh.shape[axis_x]
    nsy = mesh.shape[axis_y]

    @functools.partial(_shard_map, mesh=mesh,
                       in_specs=(jax.tree.map(spec_for, dict(g)),),
                       out_specs=P(),
                       **_SM_KW)
    def run(gl):
        state = overlay.init_state(gl, cfg, scheduler=sched)
        nx_loc = gl["opcode"].shape[0]
        ny_loc = gl["opcode"].shape[1]

        def all_reduce(x):
            if x.dtype == jnp.bool_:  # logical AND across shards
                return jax.lax.pmin(x.astype(jnp.int32), (axis_x, axis_y)).astype(jnp.bool_)
            return jax.lax.psum(x, (axis_x, axis_y))

        cycle = overlay.make_cycle_fn(
            gl, cfg,
            scheduler=sched,
            shift_e=_shard_shift(axis_x, 0, nsx),
            shift_s=_shard_shift(axis_y, 1, nsy),
            all_reduce=all_reduce,
            x0=jax.lax.axis_index(axis_x) * nx_loc,
            y0=jax.lax.axis_index(axis_y) * ny_loc,
            global_ny=gm.ny,
        )

        def cond(s):
            return (~s["done"]) & (s["cycle"] < cfg.max_cycles)

        final = jax.lax.while_loop(cond, cycle, state)
        # return per-shard values gathered to replicated full grid
        out = {
            "value": jax.lax.all_gather(final["value"], axis_y, axis=1, tiled=True),
            "cycle": final["cycle"],
            "done": final["done"],
            "delivered": final["delivered"],
            "deflections": final["deflections"],
            "busy_cycles": final["busy_cycles"],
        }
        out["value"] = jax.lax.all_gather(out["value"], axis_x, axis=0, tiled=True)
        return out

    return overlay._unpack_result(run(dict(g)), gm)
