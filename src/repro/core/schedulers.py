"""Pluggable ready-node scheduler policies for the overlay simulator.

The paper's core contribution is a *scheduler policy* choice — FIFO FCFS vs.
tagged leading-one-detect out-of-order — but richer policies (and the naive
ones the paper rejects) are needed for ablations. This module extracts the
policy behind a small protocol so the cycle kernel in
:mod:`repro.core.overlay` stays policy-agnostic:

  * ``init(g, cfg)``                      -> per-PE scheduler state pytree
  * ``on_ready(st, ix, iy, slot, ready)`` -> mark ``slot`` ready where ``ready``
  * ``select(st, idle)``                  -> (candidate slot, have) per PE
  * ``commit(st, sel, cand)``             -> consume the candidate where ``sel``
  * ``empty(st)``                         -> scalar bool: no node is queued
  * ``ready_depth(st)``                   -> [nx, ny] queued-ready count (the
    :mod:`repro.telemetry` probe; never called unless tracing is on)
  * ``sel_lat(cfg, num_words)``           -> exposed select latency (cycles)

The cycle kernel drives one fused entry point per cycle,
``step(st, idle, gate, use_pallas=...) -> (cand, have, st)``; the base class
composes ``select`` + ``commit`` so policies only implement the hooks above,
while ``ooo``/``scan``/``lru_flat`` override it to route the pick + RDY
clear through the fused Pallas kernels (:mod:`repro.kernels.lod`) when
``OverlayConfig(engine="select")`` (the deprecated ``use_pallas=True``
spelling shims to it); ``engine="megakernel"`` runs the *whole* chunk —
this protocol included — inside one Pallas kernel (see docs/megakernel.md).

All hooks are pure jnp functions of [nx, ny, ...] arrays, so every policy
works unchanged under ``jax.jit``, ``shard_map`` (state is local to a PE row)
and ``jax.vmap`` (the batched sweep engine, see
:func:`repro.core.overlay.simulate_batch`).

Registered policies:

  * ``ooo``      — packed RDY bit-flags + hierarchical OuterLOD/InnerLOD pick;
                   with criticality-ordered local memory the pick is the most
                   critical ready node (the paper's contribution).
  * ``inorder``  — FIFO in arrival order (FCFS), the prior-TDP baseline.
  * ``scan``     — the naive non-deterministic memory scan the paper rejects:
                   a rotating pointer walks the RDY vector, so the exposed
                   pick latency defaults to the word count of the scanned
                   memory (configurable via ``cfg.select_latency``).
  * ``lru_flat`` — single-level (flat) LOD with rotating least-recently-
                   granted priority and no criticality exploitation: the
                   1-cycle "fair arbiter" ablation point between ``scan`` and
                   ``ooo``.

Adding a policy: subclass :class:`Scheduler`, implement the hooks, decorate
with :func:`register`. ``cfg.scheduler = "<name>"`` then selects it in
``simulate``, ``simulate_sharded`` and ``simulate_batch`` — no cycle-kernel
edits required.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitvec


def row_gather(arr, idx):
    """arr: [nx, ny, L(, ...)], idx: [nx, ny] -> arr[x, y, idx[x, y]]."""
    idxc = jnp.clip(idx, 0, arr.shape[2] - 1)
    take = jnp.take_along_axis(
        arr, idxc.reshape(*idx.shape, 1, *(1,) * (arr.ndim - 3)), axis=2)
    return take.reshape(idx.shape + arr.shape[3:])


def _initial_ready(g):
    """Inputs with fanouts are ready at cycle 0 (they must drain tokens)."""
    is_input = (g["fanin"] == 0) & g["valid"]
    return is_input & (g["fo_count"] > 0)


def _rdy_image(need_drain):
    """[nx, ny, L] bool -> packed [nx, ny, W] uint32 RDY bit image."""
    nx, ny, L = need_drain.shape
    W = L // bitvec.FLAGS_PER_WORD
    slots = jnp.arange(L, dtype=jnp.int32)
    bit = jnp.uint32(1) << (31 - (slots % 32)).astype(jnp.uint32)
    masks = jnp.where(need_drain, bit[None, None, :], jnp.uint32(0))
    return jax.lax.reduce(
        masks.reshape(nx, ny, W, 32), jnp.uint32(0), jax.lax.bitwise_or, (3,))


def _set_rdy_bit(rdy, ix, iy, slot, on):
    nx, ny, _ = rdy.shape
    return bitvec.set_bit(
        rdy.reshape(nx * ny, -1),
        (ix * ny + iy).reshape(-1),
        slot.reshape(-1),
        on.reshape(-1),
    ).reshape(nx, ny, -1)


def _clear_selected(rdy, sel, cand):
    """Clear bit ``cand`` on PEs where ``sel``; L = W * 32."""
    nx, ny, W = rdy.shape
    L = W * bitvec.FLAGS_PER_WORD
    ix = jnp.arange(nx)[:, None] * jnp.ones((1, ny), jnp.int32)
    iy = jnp.arange(ny)[None, :] * jnp.ones((nx, 1), jnp.int32)
    word, mask = bitvec.slot_word_mask(jnp.clip(cand, 0, L - 1))
    row = rdy[ix, iy, word]
    return rdy.at[ix, iy, word].set(jnp.where(sel, row & ~mask, row))


class Scheduler:
    """Base policy. Subclasses override the hooks; see the module docstring."""

    name: str = "?"
    #: whether the policy exploits criticality-ordered local memory (used by
    #: benchmarks to choose the matching GraphMemory layout).
    wants_criticality_order: bool = True

    def sel_lat(self, cfg, num_words: int) -> int:
        """Exposed select latency in cycles (static, resolved at trace time)."""
        return cfg.sel_lat

    def init(self, g, cfg) -> dict:
        raise NotImplementedError

    def on_ready(self, st: dict, ix, iy, slot, ready) -> dict:
        raise NotImplementedError

    def select(self, st: dict, idle):
        raise NotImplementedError

    def commit(self, st: dict, sel, cand) -> dict:
        raise NotImplementedError

    def empty(self, st: dict):
        raise NotImplementedError

    def ready_depth(self, st: dict):
        """[nx, ny] int32 count of queued-ready nodes per PE — the telemetry
        probe behind :mod:`repro.telemetry`'s ready-set-depth trace. Purely
        observational: never called by the cycle kernel unless a
        ``TelemetrySpec`` asks for scheduler traces."""
        raise NotImplementedError

    def step(self, st: dict, idle, gate, *, use_pallas: bool = False):
        """Fused select + commit: the cycle kernel's per-cycle entry point.

        ``gate`` marks PEs whose pick is consumed this cycle (idle and past
        the exposed select latency); the candidate is committed where
        ``gate & have``. The default composes the two hooks, so policies only
        implementing the five base hooks work unchanged; policies with a
        fused Pallas kernel override this (``use_pallas=True``) to do the
        pick and the RDY clear in one VMEM round-trip.
        """
        cand, have = self.select(st, idle)
        return cand, have, self.commit(st, gate & have, cand)


REGISTRY: dict[str, Scheduler] = {}


def register(cls):
    """Class decorator: instantiate and add to the policy REGISTRY."""
    inst = cls()
    if inst.name in REGISTRY:
        raise ValueError(f"duplicate scheduler name {inst.name!r}")
    REGISTRY[inst.name] = inst
    return cls


def get(name: str) -> Scheduler:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


@register
class OooScheduler(Scheduler):
    """Packed RDY bit-flags + hierarchical leading-one detect (paper §II-B)."""

    name = "ooo"
    wants_criticality_order = True

    def init(self, g, cfg):
        return dict(rdy=_rdy_image(_initial_ready(g)))

    def on_ready(self, st, ix, iy, slot, ready):
        return dict(st, rdy=_set_rdy_bit(st["rdy"], ix, iy, slot, ready))

    def select(self, st, idle):
        cand = bitvec.leading_one(st["rdy"])   # most critical ready slot
        return cand, cand >= 0

    def commit(self, st, sel, cand):
        return dict(st, rdy=_clear_selected(st["rdy"], sel, cand))

    def empty(self, st):
        return (st["rdy"] == 0).all()

    def ready_depth(self, st):
        return bitvec.count_set(st["rdy"])

    def step(self, st, idle, gate, *, use_pallas=False):
        if not use_pallas:
            return super().step(st, idle, gate, use_pallas=False)
        from repro.kernels import ops  # lazy: keep core importable sans Pallas

        nx, ny, W = st["rdy"].shape
        slot, newbits = ops.schedule_step(
            st["rdy"].reshape(nx * ny, W), gate=gate.reshape(nx * ny))
        cand = slot.reshape(nx, ny)
        return cand, cand >= 0, dict(st, rdy=newbits.reshape(nx, ny, W))


@register
class InorderScheduler(Scheduler):
    """FIFO in arrival order (FCFS) — the prior-TDP baseline. Depth is the
    deadlock-free worst case: every local slot simultaneously ready."""

    name = "inorder"
    wants_criticality_order = False

    def init(self, g, cfg):
        nx, ny, L = g["opcode"].shape
        need_drain = _initial_ready(g)
        slots = jnp.arange(L, dtype=jnp.int32)
        # FIFO pre-loaded with ready inputs in ascending slot (arrival) order.
        order_key = jnp.where(need_drain, slots, L)
        fifo_init = jnp.sort(order_key, axis=-1)
        fifo = jnp.where(fifo_init < L, fifo_init, -1).astype(jnp.int32)
        return dict(
            fifo=fifo,
            head=jnp.zeros((nx, ny), jnp.int32),
            size=need_drain.sum(axis=-1).astype(jnp.int32),
        )

    def on_ready(self, st, ix, iy, slot, ready):
        fifo, head, size = st["fifo"], st["head"], st["size"]
        depth = fifo.shape[-1]
        tail = (head + size) % depth
        old = fifo[ix, iy, tail]
        fifo = fifo.at[ix, iy, tail].set(jnp.where(ready, slot, old))
        return dict(fifo=fifo, head=head, size=size + ready.astype(jnp.int32))

    def select(self, st, idle):
        return row_gather(st["fifo"], st["head"]), st["size"] > 0

    def commit(self, st, sel, cand):
        depth = st["fifo"].shape[-1]
        head = jnp.where(sel, (st["head"] + 1) % depth, st["head"])
        size = jnp.where(sel, st["size"] - 1, st["size"])
        return dict(st, head=head, size=size)

    def empty(self, st):
        return (st["size"] == 0).all()

    def ready_depth(self, st):
        return st["size"]


class _RotatingRdyScheduler(Scheduler):
    """Shared machinery: RDY bit vector scanned from a rotating pointer.

    The pick is the first ready slot at/after the pointer (wrapping), i.e.
    rotating / least-recently-granted priority — deliberately blind to the
    criticality slot ordering the ``ooo`` policy exploits.
    """

    wants_criticality_order = False

    def init(self, g, cfg):
        nx, ny, _ = g["opcode"].shape
        return dict(rdy=_rdy_image(_initial_ready(g)),
                    ptr=jnp.zeros((nx, ny), jnp.int32))

    def on_ready(self, st, ix, iy, slot, ready):
        return dict(st, rdy=_set_rdy_bit(st["rdy"], ix, iy, slot, ready))

    def select(self, st, idle):
        rdy = st["rdy"]
        hi = rdy & bitvec.mask_slots_ge(st["ptr"], rdy.shape[-1])
        cand_hi = bitvec.leading_one(hi)
        cand = jnp.where(cand_hi >= 0, cand_hi, bitvec.leading_one(rdy))
        return cand, cand >= 0

    def commit(self, st, sel, cand):
        rdy = _clear_selected(st["rdy"], sel, cand)
        L = rdy.shape[-1] * bitvec.FLAGS_PER_WORD
        ptr = jnp.where(sel, (jnp.clip(cand, 0, L - 1) + 1) % L, st["ptr"])
        return dict(rdy=rdy, ptr=ptr)

    def empty(self, st):
        return (st["rdy"] == 0).all()

    def ready_depth(self, st):
        return bitvec.count_set(st["rdy"])

    def step(self, st, idle, gate, *, use_pallas=False):
        if not use_pallas:
            return super().step(st, idle, gate, use_pallas=False)
        from repro.kernels import ops  # lazy: keep core importable sans Pallas

        nx, ny, W = st["rdy"].shape
        L = W * bitvec.FLAGS_PER_WORD
        slot, newbits = ops.rotating_schedule_step(
            st["rdy"].reshape(nx * ny, W), st["ptr"].reshape(nx * ny),
            gate.reshape(nx * ny))
        cand = slot.reshape(nx, ny)
        have = cand >= 0
        sel = gate & have
        ptr = jnp.where(sel, (cand + 1) % L, st["ptr"])
        return cand, have, dict(rdy=newbits.reshape(nx, ny, W), ptr=ptr)


@register
class ScanScheduler(_RotatingRdyScheduler):
    """The naive memory scan the paper rejects: the pick walks graph memory
    word by word, so its exposed latency defaults to the RDY word count
    (non-deterministic in hardware; modeled as the worst-case full sweep).
    Override with ``cfg.select_latency`` for a shallower exposed cost."""

    name = "scan"

    def sel_lat(self, cfg, num_words):
        if cfg.select_latency is not None:
            return cfg.select_latency
        return max(1, num_words)


@register
class LruFlatScheduler(_RotatingRdyScheduler):
    """Single-level (flat) LOD, rotating priority, 1-cycle exposed pick —
    the fair-arbiter ablation: as fast as ``ooo`` per pick but unable to
    exploit criticality ordering."""

    name = "lru_flat"


class BatchedScheduler(Scheduler):
    """Composite policy for the vmapped sweep engine.

    Maintains every member policy's state side by side plus a per-batch-
    element ``policy_id``; ``select``/``empty`` dispatch on it with
    ``jnp.select`` so one traced cycle body serves a whole
    (scheduler x latency) sweep. Inactive substates still advance (their
    updates are data-independent of the dispatch) but only the active
    policy's state ever reaches ``select``/``empty``, so each batch element
    is cycle-exact with the corresponding solo run.
    """

    name = "batched"
    wants_criticality_order = True

    def __init__(self, names: tuple[str, ...] = ()):
        self.names = tuple(names)
        self.policies = [get(n) for n in self.names]

    def sel_lat(self, cfg, num_words):
        # Placeholder: simulate_batch overwrites sel_wait/sel_lat per element.
        return 1

    def init(self, g, cfg):
        st = {n: p.init(g, cfg) for n, p in zip(self.names, self.policies)}
        st["policy_id"] = jnp.int32(0)
        return st

    def _preds(self, st):
        return [st["policy_id"] == i for i in range(len(self.policies))]

    @property
    def _solo(self):
        """Single-policy sweep: the dispatch predicate is statically true for
        member 0 and statically false for everyone else, so the per-policy
        masking and ``jnp.select`` dispatch are pruned at trace time."""
        return len(self.policies) == 1

    def on_ready(self, st, ix, iy, slot, ready):
        out = dict(st)
        for n, p in zip(self.names, self.policies):
            out[n] = p.on_ready(st[n], ix, iy, slot, ready)
        return out

    def select(self, st, idle):
        if self._solo:
            return self.policies[0].select(st[self.names[0]], idle)
        cands, haves = zip(*(p.select(st[n], idle)
                             for n, p in zip(self.names, self.policies)))
        preds = self._preds(st)
        cand = jnp.select(preds, list(cands), cands[0])
        have = jnp.select(preds, list(haves), haves[0])
        return cand, have

    def commit(self, st, sel, cand):
        out = dict(st)
        if self._solo:
            n = self.names[0]
            out[n] = self.policies[0].commit(st[n], sel, cand)
            return out
        for i, (n, p) in enumerate(zip(self.names, self.policies)):
            out[n] = p.commit(st[n], sel & (st["policy_id"] == i), cand)
        return out

    def empty(self, st):
        if self._solo:
            return self.policies[0].empty(st[self.names[0]])
        es = [p.empty(st[n]) for n, p in zip(self.names, self.policies)]
        return jnp.select(self._preds(st), es, es[0])

    def ready_depth(self, st):
        if self._solo:
            return self.policies[0].ready_depth(st[self.names[0]])
        ds = [p.ready_depth(st[n]) for n, p in zip(self.names, self.policies)]
        return jnp.select(self._preds(st), ds, ds[0])

    def step(self, st, idle, gate, *, use_pallas=False):
        out = dict(st)
        if self._solo:
            n = self.names[0]
            cand, have, out[n] = self.policies[0].step(
                st[n], idle, gate, use_pallas=use_pallas)
            return cand, have, out
        # Each member commits its own candidate under its dispatch predicate;
        # where the predicate holds, the member's candidate IS the dispatched
        # candidate, so this equals select-then-masked-commit exactly.
        preds = self._preds(st)
        cands, haves = [], []
        for i, (n, p) in enumerate(zip(self.names, self.policies)):
            c, h, out[n] = p.step(st[n], idle, gate & preds[i],
                                  use_pallas=use_pallas)
            cands.append(c)
            haves.append(h)
        return (jnp.select(preds, cands, cands[0]),
                jnp.select(preds, haves, haves[0]), out)
