"""Dataflow-graph workload generators.

The paper evaluates on "dataflow graphs extracted from sparse matrix
factorization kernels" with a few hundred to >100K nodes/edges. We extract
the exact same structure: the fine-grained operator DAG of a right-looking
sparse LU factorization (Doolittle, no pivoting) with symbolic fill-in, where
  L[i,k]   = A[i,k] / U[k,k]                      (DIV node)
  A[i,j]  -= L[i,k] * U[k,j]                      (MUL + SUB nodes)
Every matrix entry version is a dataflow token; the DAG is exactly the data
dependences of the factorization.

Also: layered random DAGs (controllable width/fanout), reduction trees and
chains for micro-benchmarks and property tests — plus an on-disk graph cache
(:func:`cached_graph`) and the paper-scale :func:`fig1_full` constructor so
benchmarks don't pay the Python DAG-elimination loop on every run.
"""
from __future__ import annotations

import os
from typing import Callable

import numpy as np

from .graph import OP_ADD, OP_DIV, OP_MUL, OP_SUB, DataflowGraph, GraphBuilder


def _lu_eliminate(b: GraphBuilder, rows_map: list[dict[int, int]]) -> DataflowGraph:
    """Right-looking Doolittle elimination over a dict-of-rows pattern.

    ``rows_map[i]`` maps column -> node id of the current value of A[i, j].
    Fill-in is materialized as SUB from a zero input (token semantics).
    """
    n = len(rows_map)
    for k in range(n):
        pivot = rows_map[k][k]
        for i in range(k + 1, n):
            if k not in rows_map[i]:
                continue
            lik = b.op(OP_DIV, rows_map[i][k], pivot)  # L[i,k]
            del rows_map[i][k]
            for j, ukj in list(rows_map[k].items()):
                if j <= k:
                    continue
                prod = b.op(OP_MUL, lik, ukj)
                if j in rows_map[i]:
                    rows_map[i][j] = b.op(OP_SUB, rows_map[i][j], prod)
                else:  # fill-in: 0 - prod == SUB from a zero input
                    zero = b.input(0.0)
                    rows_map[i][j] = b.op(OP_SUB, zero, prod)
    return b.build()


def _pattern_inputs(b: GraphBuilder, n: int, keep, rng) -> list[dict[int, int]]:
    rows_map: list[dict[int, int]] = []
    for i in range(n):
        row: dict[int, int] = {}
        for j in range(n):
            if i == j or keep(i, j):
                val = rng.uniform(0.5, 2.0) * (n if i == j else 1.0)
                row[j] = b.input(val)
        rows_map.append(row)
    return rows_map


def sparse_lu_graph(n: int, density: float = 0.05, seed: int = 0) -> DataflowGraph:
    """Operator DAG of sparse LU factorization of a random n x n matrix.

    Node/edge count grows roughly with fill-in; use :func:`lu_size_for_nodes`
    to pick ``n`` for a target node budget.
    """
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    rows_map = _pattern_inputs(b, n, lambda i, j: rng.random() < density, rng)
    return _lu_eliminate(b, rows_map)


def arrow_lu_graph(blocks: int, block_size: int, border: int, seed: int = 0) -> DataflowGraph:
    """LU DAG of a bordered block-diagonal ("arrow") matrix.

    This is the canonical structure of circuit/power-grid matrices after
    ordering: ``blocks`` independent dense diagonal blocks (bulk parallelism
    that fills every PE's ready queue) coupled by a dense border whose
    update chains run through *every* block (the critical path). In-order
    FCFS buries the border chain behind block bulk; criticality-ordered OoO
    keeps it moving — the workload family behind the paper's Fig. 1 regime.
    """
    rng = np.random.default_rng(seed)
    n = blocks * block_size + border

    def keep(i, j):
        bi, bj = i // block_size, j // block_size
        in_border = i >= blocks * block_size or j >= blocks * block_size
        return in_border or bi == bj

    b = GraphBuilder()
    rows_map = _pattern_inputs(b, n, keep, rng)
    return _lu_eliminate(b, rows_map)


def banded_lu_graph(rows: int, band: int, seed: int = 0, inband_density: float = 1.0) -> DataflowGraph:
    """LU factorization DAG of a banded matrix (e.g. a discretized PDE /
    circuit matrix after ordering). Structured sparsity keeps the available
    parallelism bounded (~band^2) while the critical path grows with ``rows``
    — the regime where the paper's criticality-aware scheduling pays off."""
    rng = np.random.default_rng(seed)
    b = GraphBuilder()

    def keep(i, j):
        return abs(i - j) <= band and (inband_density >= 1.0 or rng.random() < inband_density)

    rows_map = _pattern_inputs(b, rows, keep, rng)
    return _lu_eliminate(b, rows_map)


def elimination_tree_graph(
    depth: int, chain_len: int = 16, leaf_width: int = 32, seed: int = 0
) -> DataflowGraph:
    """Supernodal elimination-tree DAG (sparse Cholesky/LU structure).

    ``2**depth`` leaves of wide independent work (the bushy bottom of a
    nested-dissection elimination tree) feed binary merges, each followed by
    a sequential update chain of length ``chain_len`` (the separator/
    supernode factorization). Root-ward chains are the critical path; leaf
    bulk floods every PE's ready queue — the mixed regime where FCFS hurts.
    """
    rng = np.random.default_rng(seed)
    b = GraphBuilder()

    def leaf() -> int:
        vals = [b.input(rng.uniform(0.5, 2.0)) for _ in range(leaf_width)]
        while len(vals) > 1:
            nxt = [b.op(OP_ADD, vals[2 * i], vals[2 * i + 1]) for i in range(len(vals) // 2)]
            if len(vals) % 2:
                nxt.append(vals[-1])
            vals = nxt
        return vals[0]

    def rec(d: int) -> int:
        if d == 0:
            return leaf()
        a = rec(d - 1)
        c = rec(d - 1)
        v = b.op(OP_ADD, a, c)
        for _ in range(chain_len):
            v = b.op(OP_MUL, v, b.input(rng.uniform(0.9, 1.1)))
        return v

    rec(depth)
    return b.build()


def lu_size_for_nodes(target_nodes: int) -> tuple[int, float]:
    """Heuristic (n, density) whose LU DAG lands near ``target_nodes``.

    Random-pattern sparse LU fills in almost densely during elimination, so
    the operator count tracks the *dense*-LU flop count: nodes ~= 1.15 *
    n^3 / 3, measured over this table's density ramp (the old
    ``(n d)^2 n / 3`` input-pattern estimate undershot ~30x at scale).
    Densities ramp down with ``n`` so the *input* pattern stays sparse —
    the structure of the paper's workloads — while fill-in does the growing.
    """
    for n, d in [(16, 0.25), (24, 0.25), (32, 0.25), (48, 0.2), (64, 0.15),
                 (80, 0.12), (96, 0.1), (108, 0.1), (128, 0.09), (160, 0.08),
                 (192, 0.07)]:
        if 1.15 * n ** 3 / 3 >= target_nodes:
            return n, d
    return 256, 0.06


# ---------------------------------------------------------------------------
# On-disk graph cache: the big LU DAGs are built by Python elimination loops
# (seconds to minutes at fig1-full scale) but are pure functions of their
# seeds, so benchmarks memoize them as npz files under experiments/.
# ---------------------------------------------------------------------------

def graph_cache_dir() -> str:
    """Cache root: ``$REPRO_GRAPH_CACHE`` or ``./experiments/graph_cache``."""
    return os.environ.get(
        "REPRO_GRAPH_CACHE",
        os.path.join(os.getcwd(), "experiments", "graph_cache"))


def save_graph(g: DataflowGraph, path: str) -> None:
    import tempfile

    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # Unique tmp + atomic rename: concurrent cold-starting bench runs never
    # interleave writes or publish a torso (last replace wins, both valid).
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(
                f, opcode=g.opcode, fanout_ptr=g.fanout_ptr,
                fanout_dst=g.fanout_dst, fanout_slot=g.fanout_slot,
                initial_values=g.initial_values)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_graph(path: str) -> DataflowGraph:
    with np.load(path) as z:
        return DataflowGraph(
            opcode=z["opcode"], fanout_ptr=z["fanout_ptr"],
            fanout_dst=z["fanout_dst"], fanout_slot=z["fanout_slot"],
            initial_values=z["initial_values"])


def cached_graph(name: str, builder: Callable[[], DataflowGraph], *,
                 cache_dir: str | None = None) -> DataflowGraph:
    """Build-once graph memoization: load ``<cache_dir>/<name>.npz`` if it
    exists, else run ``builder`` and persist its result there.

    ``name`` must encode every builder parameter (sizes, seeds) — the cache
    trusts it blindly. Delete the file (or point ``$REPRO_GRAPH_CACHE``
    elsewhere) to force a rebuild.
    """
    path = os.path.join(cache_dir or graph_cache_dir(), f"{name}.npz")
    if os.path.exists(path):
        return load_graph(path)
    g = builder()
    save_graph(g, path)
    return g


def fig1_full(target_nodes: int = 470_000, seed: int = 0, *,
              cache: bool = True, cache_dir: str | None = None) -> DataflowGraph:
    """The paper's fig1-full-scale workload: a sparse-LU DAG near ~470K nodes.

    ``(n, density)`` come from :func:`lu_size_for_nodes`, so the constructor
    is calibrated rather than guessed; the result is cached on disk (the
    elimination loop takes minutes at this scale — the cache makes every
    benchmark run after the first load in milliseconds).
    """
    n, d = lu_size_for_nodes(target_nodes)
    name = f"fig1_full_lu_n{n}_d{d}_seed{seed}"
    builder = lambda: sparse_lu_graph(n, d, seed=seed)
    if not cache:
        return builder()
    return cached_graph(name, builder, cache_dir=cache_dir)


#: fig1-family graphs the BENCH ``megakernel`` section (and the tier-1
#: ``python -m repro.kernels --smoke`` gate) simulate — CI pre-warms these so
#: neither ever pays the Python elimination loop.
MEGAKERNEL_BENCH_GRAPHS = ("arrow_b4_s10_w8_seed3", "arrow_b8_s10_w8_seed3")


def service_stream(n_queries: int = 32, distinct: int = 8,
                   seed: int = 0) -> list:
    """Deterministic replayed graph stream for the placement service.

    Models the fleet workload the service layer amortizes: ``distinct``
    small fig1-family arrow-LU graphs, each appearing once up front, then
    ``n_queries - distinct`` repeats — a deterministic round-robin pass
    first (when the stream is long enough, every distinct graph is
    guaranteed at least one repeat, so cached-vs-fresh benchmark rows exist
    for all of them), the rest drawn from a fixed PRNG. A stream of 32
    queries over 8 graphs carries 75% repeats, all answerable from the
    content-hash cache with zero simulations. Returns ``[(name,
    DataflowGraph)]``; both the BENCH ``service`` section and the
    ``python -m repro.service --smoke`` gate replay it.
    """
    if not 1 <= distinct <= n_queries:
        raise ValueError(
            f"need 1 <= distinct <= n_queries, got {distinct}/{n_queries}")
    variants = []
    for blocks in (2, 3, 4, 5):
        for gseed in (1, 2):
            variants.append((f"svc_arrow_b{blocks}_s6_w4_seed{gseed}",
                             (blocks, 6, 4, gseed)))
    if distinct > len(variants):
        raise ValueError(f"at most {len(variants)} distinct stream graphs, "
                         f"got {distinct}")
    graphs = [(name, arrow_lu_graph(b, s, w, seed=sd))
              for name, (b, s, w, sd) in variants[:distinct]]
    rng = np.random.default_rng(seed)
    stream = list(graphs)
    n_repeats = n_queries - distinct
    stream += [graphs[i % distinct] for i in range(min(n_repeats, distinct))]
    for _ in range(n_repeats - distinct):
        stream.append(graphs[int(rng.integers(0, distinct))])
    return stream


def warm_cache(names: list[str] | None = None) -> dict[str, int]:
    """Build (or load) the cacheable benchmark DAGs into the graph cache.

    ``python -m repro.core.workloads [name ...]`` — CI runs this before the
    bench driver so a restored ``experiments/graph_cache/`` turns the
    minutes-long Python elimination loops into millisecond npz loads, and a
    cold cache is populated once per workload-code change (the cache key is
    a hash of this file). Known names: ``fig1_full``, the benchmark sweep's
    ``arrow_b{blocks}_s{size}_w{border}_seed{seed}`` family, and the
    ``megakernel_bench`` alias (expands to :data:`MEGAKERNEL_BENCH_GRAPHS`).
    Returns ``{name: num_nodes}`` for the log.
    """
    names = names or ["fig1_full"]
    built: dict[str, int] = {}
    for name in names:
        if name == "fig1_full":
            built[name] = fig1_full().num_nodes
            continue
        if name == "megakernel_bench":
            built.update(warm_cache(list(MEGAKERNEL_BENCH_GRAPHS)))
            continue
        if name.startswith("arrow_"):
            parts = dict(
                (p[0], int(p[1:])) for p in name.split("_")[1:]
                if p[0] in "bsw" and p[1:].isdigit())
            seed = int(name.rsplit("seed", 1)[1]) if "seed" in name else 0
            if {"b", "s", "w"} <= parts.keys():
                g = cached_graph(name, lambda: arrow_lu_graph(
                    parts["b"], parts["s"], parts["w"], seed=seed))
                built[name] = g.num_nodes
                continue
        raise ValueError(f"don't know how to build cached graph {name!r}")
    return built


def layered_dag(
    num_layers: int,
    width: int,
    fanout: int = 2,
    seed: int = 0,
    skew: float = 0.0,
) -> DataflowGraph:
    """Random layered DAG: each non-input node consumes 2 values from earlier
    layers. ``skew`` > 0 concentrates edges on a critical "spine" so that
    criticality-aware scheduling has something to exploit."""
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    layers = [[b.input(rng.uniform(0.5, 2.0)) for _ in range(width)]]
    ops = np.array([OP_ADD, OP_SUB, OP_MUL], dtype=np.int64)
    for li in range(1, num_layers):
        prev = layers[-1]
        cur = []
        for wi in range(width):
            if skew > 0 and wi == 0:
                a = prev[0]  # spine: long dependence chain
            else:
                a = prev[rng.integers(len(prev))]
            src_layer = layers[rng.integers(max(0, li - fanout), li)]
            bb = src_layer[rng.integers(len(src_layer))]
            cur.append(b.op(int(ops[rng.integers(3)]), a, bb))
        layers.append(cur)
    # Reduce the last layer so the DAG has few sinks (like a solve result).
    frontier = layers[-1]
    while len(frontier) > 1:
        frontier = [
            b.op(OP_ADD, frontier[2 * i], frontier[2 * i + 1])
            for i in range(len(frontier) // 2)
        ] + ([frontier[-1]] if len(frontier) % 2 else [])
    return b.build()


def reduction_tree(leaves: int, seed: int = 0) -> DataflowGraph:
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    frontier = [b.input(rng.uniform(0.5, 2.0)) for _ in range(leaves)]
    while len(frontier) > 1:
        nxt = [
            b.op(OP_ADD, frontier[2 * i], frontier[2 * i + 1])
            for i in range(len(frontier) // 2)
        ]
        if len(frontier) % 2:
            nxt.append(frontier[-1])
        frontier = nxt
    return b.build()


def chain(length: int, seed: int = 0) -> DataflowGraph:
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    v = b.input(rng.uniform(0.5, 2.0))
    for _ in range(length):
        c = b.input(rng.uniform(0.5, 2.0))
        v = b.op(OP_ADD, v, c)
    return b.build()


def random_dag(num_nodes: int, seed: int = 0, input_frac: float = 0.2) -> DataflowGraph:
    """Unstructured random DAG for property tests (edges i -> j only if i < j)."""
    rng = np.random.default_rng(seed)
    b = GraphBuilder()
    ids: list[int] = []
    n_inputs = max(2, int(num_nodes * input_frac))
    ops = [OP_ADD, OP_SUB, OP_MUL, OP_DIV]
    for i in range(num_nodes):
        if i < n_inputs:
            ids.append(b.input(rng.uniform(0.5, 2.0)))
        else:
            a, c = rng.integers(0, i, size=2)
            ids.append(b.op(ops[rng.integers(4)], ids[a], ids[c]))
    return b.build()


if __name__ == "__main__":
    import sys

    for _name, _nodes in warm_cache(sys.argv[1:] or None).items():
        print(f"{_name}: {_nodes} nodes (cache: {graph_cache_dir()})")
