"""Packed RDY bit-flag vectors (paper §II-B), in JAX.

Slot ``s`` of a PE maps to word ``s // 32``, bit position ``31 - s % 32`` —
slot 0 occupies the *most significant* bit of word 0, so the paper's
"leading-one detector" (find the first 1 scanning from the MSB of word 0)
returns the lowest slot index == the most critical ready node.

These are the pure-jnp reference semantics; ``repro.kernels.lod`` implements
the same hierarchical detect as a Pallas TPU kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FLAGS_PER_WORD = 32
_U32 = jnp.uint32


def slot_word_mask(slot):
    """slot -> (word index, uint32 single-bit mask)."""
    slot = slot.astype(jnp.int32)
    word = slot // FLAGS_PER_WORD
    bitpos = (31 - (slot % FLAGS_PER_WORD)).astype(_U32)
    return word, (_U32(1) << bitpos)


def set_bit(bits, pe, slot, on):
    """Set/clear one bit per PE row. bits: [..., P, W]; pe/slot/on: [..., P]."""
    word, mask = slot_word_mask(slot)
    row = bits[..., pe, word]
    new = jnp.where(on, row | mask, row)
    return bits.at[..., pe, word].set(new)


def test_bit(bits, pe, slot):
    word, mask = slot_word_mask(slot)
    return (bits[..., pe, word] & mask) != 0


def smear(w):
    """Propagate the leading one to all lower bits (uint32)."""
    w = w | (w >> 1)
    w = w | (w >> 2)
    w = w | (w >> 4)
    w = w | (w >> 8)
    w = w | (w >> 16)
    return w


def popcount(w):
    """SWAR population count (uint32) — the form the Pallas kernel uses."""
    w = w - ((w >> 1) & _U32(0x55555555))
    w = (w & _U32(0x33333333)) + ((w >> 2) & _U32(0x33333333))
    w = (w + (w >> 4)) & _U32(0x0F0F0F0F)
    return (w * _U32(0x01010101)) >> 24


def mask_slots_ge(ptr, W):
    """[...] slot pointer -> [..., W] uint32 mask of slots >= ptr.

    Slot ``s`` lives at word s // 32, bit position 31 - s % 32, so within the
    pointer's word the surviving bits are positions 0 .. 31 - ptr % 32. This
    is the rotating-priority window of the ``scan``/``lru_flat`` policies;
    ``repro.kernels.lod`` implements the same mask inside the Pallas rotating
    select kernel.
    """
    word_ids = jnp.arange(W, dtype=jnp.int32)
    pw = ptr // FLAGS_PER_WORD
    pb = (ptr % FLAGS_PER_WORD).astype(_U32)
    full = _U32(0xFFFFFFFF)
    eq = (full >> pb)[..., None]
    return jnp.where(
        word_ids > pw[..., None], full,
        jnp.where(word_ids < pw[..., None], _U32(0), eq))


def lod_word(w):
    """Leading-one position inside a word: 0 == MSB. Undefined for w == 0."""
    # clz(w) = 32 - popcount(smear(w)); leading-one slot offset == clz.
    return (_U32(32) - popcount(smear(w))).astype(jnp.int32)


def leading_one(bits):
    """Hierarchical leading-one detect over packed rows.

    bits: [..., W] uint32. Returns int32 slot index of the first set flag in
    (word, MSB-first-bit) order, or -1 if the row is empty. This is the jnp
    reference for the OuterLOD/InnerLOD circuit pair.
    """
    w = bits.shape[-1]
    nonzero = bits != 0
    any_set = nonzero.any(axis=-1)
    # OuterLOD: first nonzero word (argmax returns the first True).
    word_idx = jnp.argmax(nonzero, axis=-1).astype(jnp.int32)
    sel = jnp.take_along_axis(bits, word_idx[..., None].astype(jnp.int32), axis=-1)[..., 0]
    # InnerLOD: leading-one position within the selected word.
    slot = word_idx * FLAGS_PER_WORD + lod_word(sel)
    return jnp.where(any_set, slot, jnp.int32(-1))


def count_set(bits):
    """Total set flags per row ([..., W] -> [...])."""
    return popcount(bits).astype(jnp.int32).sum(axis=-1)
