"""Cycle-accurate Hoplite NoC model (Kapre & Gray, FPL'15) in JAX.

Hoplite is a unidirectional 2D torus with deflection routing and no
buffering: each router owns two pipeline registers (E and S outputs), takes
inputs from its west and north neighbours plus a local PE injection port, and
routes dimension-ordered (X then Y).

Arbitration (documented policy, faithful to Hoplite's austere router):
  * N input has priority (it already turned onto the Y ring);
  * a W packet that wants S/eject but loses arbitration deflects E (stays on
    the X ring and comes around);
  * a N packet never needs deflection: it only competes for S/eject and wins
    both (N at destination always ejects because N has eject priority);
  * PE injection is lowest priority and stalls until its port is free;
    a local packet (dst == self) consumes the eject port for one cycle.

State is SoA: a packet field dict of [nx, ny] arrays. Torus links are
``jnp.roll`` on a single device; the shard_map overlay swaps in
ppermute-backed shifts (ICI hop == NoC hop).
"""
from __future__ import annotations

import jax.numpy as jnp

PKT_FIELDS = ("valid", "dst_x", "dst_y", "dst_slot", "opidx", "value")


def empty_packets(nx: int, ny: int):
    z = lambda dt: jnp.zeros((nx, ny), dtype=dt)
    return dict(
        valid=z(jnp.bool_), dst_x=z(jnp.int32), dst_y=z(jnp.int32),
        dst_slot=z(jnp.int32), opidx=z(jnp.int32), value=z(jnp.float32),
    )


def pk_where(cond, a, b):
    return {k: jnp.where(cond, a[k], b[k]) for k in PKT_FIELDS}


def pk_invalidate(p, keep):
    out = dict(p)
    out["valid"] = p["valid"] & keep
    return out


def roll_shift_e(link_e):
    """Packet on (x, y)'s E register arrives at (x+1, y)'s W input."""
    return {k: jnp.roll(v, 1, axis=0) for k, v in link_e.items()}


def roll_shift_s(link_s):
    return {k: jnp.roll(v, 1, axis=1) for k, v in link_s.items()}


def router_cycle(link_e, link_s, inject, *, shift_e=roll_shift_e, shift_s=roll_shift_s,
                 x0=0, y0=0, eject_capacity=1, eject_policy="n_first"):
    """One NoC cycle for every router in parallel.

    Args:
      link_e, link_s: packet dicts on the E/S output registers.
      inject: packet dict offered by each PE this cycle.
      shift_e/shift_s: torus shift implementations (roll or ppermute).
      x0, y0: global coordinate offsets of this shard's router tile (0 on a
        single device; axis_index * tile under shard_map).
      eject_capacity: PE packets/cycle. 2 models the paper's §II-C BRAM
        multipumping (extra virtual write ports): N and W can eject in the
        same cycle, removing the W-at-destination deflection.
      eject_policy: single-port eject arbitration. ``"n_first"`` (default,
        Hoplite's austere rule: N always beats W); ``"priority"`` picks the
        packet targeting the more critical destination slot — with
        criticality-ordered local memory the lower ``dst_slot`` IS the higher
        static criticality (§II-C hints the W/N pick could look at slot
        priority). The losing N packet deflects south around the Y ring, the
        losing W packet deflects east, so the router stays bufferless.
        Irrelevant when ``eject_capacity >= 2`` (no eject contention).

    Returns:
      (new_link_e, new_link_s, ejects [list of packet dicts], accepted,
       deflected) — ``deflected`` is a dict of [nx, ny] int32 per-router
      counts of in-flight packets this router deflected (kept circulating
      after losing arbitration) this cycle, split by cause:
        * ``"noc"``   — route contention away from the destination: a W
          packet that wanted the S turn but lost it to a continuing N packet;
        * ``"eject"`` — eject-port contention AT the destination: a packet
          that reached its target router but lost the single eject port and
          must come around the ring again.
      The split feeds the ``noc_deflections`` / ``eject_deflections`` stats
      and the per-link telemetry traces (:mod:`repro.telemetry`).
    """
    nx, ny = link_e["valid"].shape
    my_x = jnp.arange(nx, dtype=jnp.int32)[:, None] + x0
    my_y = jnp.arange(ny, dtype=jnp.int32)[None, :] + y0

    w_in = shift_e(link_e)   # arrives from the west
    n_in = shift_s(link_s)   # arrives from the north

    def at_dst(p):
        return p["valid"] & (p["dst_x"] == my_x) & (p["dst_y"] == my_y)

    def wants_e(p):
        return p["valid"] & (p["dst_x"] != my_x)

    def wants_s(p):
        return p["valid"] & (p["dst_x"] == my_x) & (p["dst_y"] != my_y)

    # --- eject arbitration ---
    n_at, w_at = at_dst(n_in), at_dst(w_in)
    if eject_capacity >= 2:
        n_ej, w_ej = n_at, w_at                   # both may eject
    elif eject_policy == "priority":
        # Criticality-aware pick: lower dst_slot == higher criticality rank
        # in the destination PE's (criticality-ordered) local memory.
        w_wins = w_at & n_at & (w_in["dst_slot"] < n_in["dst_slot"])
        n_ej = n_at & ~w_wins
        w_ej = w_at & (~n_at | w_wins)
    elif eject_policy == "n_first":
        n_ej = n_at
        w_ej = w_at & ~n_ej
    else:
        raise ValueError(
            f"unknown eject_policy {eject_policy!r}; use 'n_first' or 'priority'")
    eject = pk_where(n_ej, n_in, pk_invalidate(w_in, w_ej & ~n_ej))
    eject2 = pk_invalidate(w_in, w_ej & n_ej) if eject_capacity >= 2 else None

    # --- S output: N continues south unless it ejected (an N packet that
    #     lost a priority eject deflects south around the Y ring) ---
    n_takes_s = n_in["valid"] & ~n_ej
    w_takes_s = wants_s(w_in) & ~n_takes_s
    # --- E output: W continues east, or deflects E on any lost arbitration ---
    w_takes_e = wants_e(w_in) | (wants_s(w_in) & n_takes_s) | (at_dst(w_in) & ~w_ej)

    deflected = dict(
        noc=(wants_s(w_in) & n_takes_s).astype(jnp.int32),
        eject=((w_at & ~w_ej).astype(jnp.int32)
               + (n_at & ~n_ej).astype(jnp.int32)))

    # --- PE injection (lowest priority) ---
    inj_local = at_dst(inject)
    inj_e = wants_e(inject) & ~w_takes_e
    inj_s = wants_s(inject) & ~n_takes_s & ~w_takes_s
    if eject_capacity >= 2:
        free2 = ~eject2["valid"]
        inj_ej = inj_local & (~eject["valid"] | free2)
        inj_to_slot2 = inj_ej & eject["valid"]    # first slot taken by network
        eject2 = pk_where(inj_to_slot2, inject, eject2)
        eject = pk_where(inj_ej & ~inj_to_slot2, inject, eject)
    else:
        inj_ej = inj_local & ~eject["valid"]
        eject = pk_where(inj_ej, inject, eject)
    accepted = inj_e | inj_s | inj_ej

    new_e = pk_where(w_takes_e, w_in, pk_invalidate(inject, inj_e))
    new_s = pk_where(n_takes_s, n_in,
                     pk_where(w_takes_s, w_in, pk_invalidate(inject, inj_s)))
    ejects = [eject] if eject2 is None else [eject, eject2]
    return new_e, new_s, ejects, accepted, deflected


def links_empty(link_e, link_s):
    return ~(link_e["valid"].any() | link_s["valid"].any())
