"""The batched placement-and-simulation service.

:class:`PlacementService` answers streams of :class:`PlacementQuery`
requests — the fleet-scale spelling of the paper's one-time static
labeling/placement pass. Three amortization layers, in lookup order:

1. **Content-hash result cache** (:mod:`repro.service.cache`): repeat
   graphs are free. A hit returns the cached placement and bit-exact cycle
   counts with ZERO simulations (counter-asserted in tests and the BENCH
   ``service`` section) — bit-determinism is what makes a cached integer
   indistinguishable from a fresh one.
2. **Batched search**: cache-missing queries that share graph tables and
   static annealer knobs fan out through ONE vmapped parallel-tempering
   program (:func:`repro.place.anneal.anneal_placements` — many
   independent ladders in a single XLA dispatch, each element bit-identical
   to its solo run). Guided queries share ONE surrogate per (graph, grid)
   family, fitted on first use and reused for the rest of the stream
   (``Guide.coarsen`` transfers it down the multilevel pipeline's scales).
3. **Shape-class simulation**: placed memories are padded to each query
   group's joint ``(lmax, emax)`` shape class
   (:func:`repro.place.api.shape_class`), so mixed-graph batches reuse one
   jit cache entry per shape class instead of recompiling per graph.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .cache import CachedResult, ResultCache
from .hashing import graph_digest, query_key

#: SimResult integer counters worth caching alongside the cycle count.
_STAT_FIELDS = ("delivered", "deflections", "busy_cycles",
                "noc_deflections", "eject_deflections")


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementQuery:
    """One (graph, grid, objective, budget) request.

    ``objective`` — ``"cycles"`` (resolve a placement, then simulate it:
    the answer carries bit-exact cycle counts) or ``"cost"`` (resolve only;
    the answer carries the integer placement-model cost and runs zero
    simulations — the in-loop proxy objective).

    ``budget`` — total annealer proposals (``replicas * rounds * steps``)
    for search placements. ``None`` keeps the spec's own knobs; an explicit
    budget deterministically derives ``rounds`` from the default ladder
    (ignored for static strategies and for specs with explicit ``anneal``
    knobs, which win).

    ``cfg`` — the :class:`~repro.core.overlay.OverlayConfig` to answer
    under (``None`` = defaults); ``cfg.placement`` accepts
    ``str | PlacementSpec | None`` like everywhere else.
    """

    graph: Any
    nx: int
    ny: int
    objective: str = "cycles"
    budget: int | None = None
    cfg: Any = None

    def __post_init__(self):
        if self.objective not in ("cycles", "cost"):
            raise ValueError(
                f"objective must be 'cycles' or 'cost', "
                f"got {self.objective!r}")
        if self.nx < 1 or self.ny < 1:
            raise ValueError(f"grid must be >= 1x1, got {self.nx}x{self.ny}")
        if self.budget is not None and self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")


@dataclasses.dataclass(frozen=True, eq=False)
class QueryResult:
    """Answer to one query. ``cached`` marks a zero-simulation cache hit."""

    key: int
    node_pe: np.ndarray
    objective: str
    cycles: int | None
    cost: int | None
    stats: dict
    cached: bool


def effective_config(q: PlacementQuery):
    """The canonical OverlayConfig a query actually runs under.

    Folds ``q.budget`` into the placement spec (deterministically — the
    same query always derives the same knobs, so its cache key is stable)
    and returns a config whose ``placement`` is the final canonical spec.
    """
    from ..core.overlay import OverlayConfig
    from ..place.spec import SEARCH_STRATEGIES, AnnealConfig

    cfg = q.cfg if q.cfg is not None else OverlayConfig()
    spec = cfg.placement  # canonical PlacementSpec via __post_init__
    if (q.budget is not None and spec.strategy in SEARCH_STRATEGIES
            and spec.anneal is None):
        base = AnnealConfig(seed=spec.seed)
        rounds = max(1, q.budget // (base.replicas * base.steps))
        spec = dataclasses.replace(
            spec, anneal=dataclasses.replace(base, rounds=rounds))
    return dataclasses.replace(cfg, placement=spec)


def _result_stats(res) -> dict:
    return {k: int(getattr(res, k)) for k in _STAT_FIELDS}


class PlacementService:
    """Answer placement queries with caching, batching, and amortization.

    ``cache_dir`` (or ``$REPRO_SERVICE_CACHE`` via
    :func:`repro.service.cache.service_cache_dir`) turns on on-disk
    persistence; the default is a process-local LRU so benchmark hit/miss
    counters stay deterministic.
    """

    def __init__(self, cache: ResultCache | None = None, *,
                 capacity: int = 4096, cache_dir: str | None = None):
        self.cache = cache if cache is not None else ResultCache(
            capacity=capacity, directory=cache_dir)
        self._guides: dict = {}   # surrogate models shared across the stream
        self.counters = {
            "queries": 0,          # queries answered
            "simulations": 0,      # engine runs (cache hits add zero)
            "anneals": 0,          # search placements resolved
            "batched_anneals": 0,  # ... of which rode a vmapped fan-out
            "surrogate_fits": 0,   # guided-search models fitted (not reused)
        }

    # -- surrogate sharing --------------------------------------------------

    def _guide_for(self, g, digest: bytes, nx: int, ny: int, spec):
        """One fitted surrogate per (graph, grid, fit knobs) for the whole
        stream; ``place.api.resolve`` coarsen-transfers it inside the
        multilevel pipeline."""
        key = (digest, nx, ny, spec.metric, spec.guide_train, spec.seed,
               spec.anneal_config.crit_scale)
        model = self._guides.get(key)
        if model is None:
            from .. import surrogate as sg

            model, _, cycles = sg.fit_from_sim(
                g, nx, ny, n_train=spec.guide_train, seed=spec.seed,
                metric=spec.metric, crit_scale=spec.anneal_config.crit_scale)
            self.counters["surrogate_fits"] += 1
            self.counters["simulations"] += len(cycles)
            self._guides[key] = model
        return model

    # -- placement resolution ----------------------------------------------

    def _resolve_placements(self, items: list[dict]) -> None:
        """Fill ``item["node_pe"]`` (+ ``item["cost"]``) for every item.

        Plain-anneal queries sharing (graph, grid, metric, static annealer
        knobs) batch through :func:`repro.place.anneal.anneal_placements` —
        one vmapped XLA program per group; everything else resolves solo
        via :func:`repro.place.api.resolve`.
        """
        from ..place import anneal_placements
        from ..place.api import resolve

        groups: dict = {}
        for it in items:
            spec = it["cfg"].placement
            acfg = spec.anneal_config
            if spec.strategy == "anneal" and spec.guide is None:
                gk = (it["digest"], it["nx"], it["ny"], spec.metric,
                      spec.init, acfg.replicas, acfg.rounds, acfg.steps,
                      acfg.crit_scale, acfg.pressure_weight)
                groups.setdefault(gk, []).append(it)
            else:
                groups.setdefault(id(it), []).append(it)

        for members in groups.values():
            it0 = members[0]
            spec0 = it0["cfg"].placement
            if (len(members) > 1 and spec0.strategy == "anneal"
                    and spec0.guide is None):
                inits = []
                for it in members:
                    sp = it["cfg"].placement
                    inits.append(None if sp.init == "random" else resolve(
                        it["graph"], it["nx"], it["ny"],
                        dataclasses.replace(sp, strategy=sp.init)))
                results = anneal_placements(
                    it0["graph"], it0["nx"], it0["ny"],
                    [it["cfg"].placement.anneal_config for it in members],
                    metric=spec0.metric, inits=inits)
                for it, r in zip(members, results):
                    it["node_pe"] = r.node_pe
                    it["cost"] = r.cost
                self.counters["anneals"] += len(members)
                self.counters["batched_anneals"] += len(members)
                continue
            for it in members:
                spec = it["cfg"].placement
                guide = None
                if spec.guide == "surrogate":
                    guide = self._guide_for(it["graph"], it["digest"],
                                            it["nx"], it["ny"], spec)
                it["node_pe"] = resolve(it["graph"], it["nx"], it["ny"],
                                        spec, guide_model=guide)
                it["cost"] = None
                if spec.strategy in ("anneal", "multilevel"):
                    self.counters["anneals"] += 1

    def _model_cost(self, it: dict) -> int:
        """Integer placement-model cost of a resolved item (cost objective
        for items whose search didn't already report one)."""
        from ..place.cost import build_cost_model

        spec = it["cfg"].placement
        acfg = spec.anneal_config
        model = build_cost_model(
            it["graph"], it["nx"], it["ny"], metric=spec.metric,
            crit_scale=acfg.crit_scale,
            pressure_weight=acfg.pressure_weight)
        return int(np.asarray(model.cost(np.asarray(it["node_pe"]))))

    # -- simulation ---------------------------------------------------------

    def _simulate(self, items: list[dict]) -> None:
        """Simulate resolved items, shape-class-grouped.

        Items sharing a grid + sim config land in one padded ``(lmax,
        emax)`` shape class, so ``_run_batch_jit`` compiles once per class
        even when the group mixes graphs of different sizes (the
        ``place.evaluate_placements`` shape-churn fix, applied streamwide).
        """
        from ..core import schedulers
        from ..core.overlay import _simulate_batch
        from ..place.api import (_latency_depends_on_words, shape_class,
                                 uniform_graph_memories)

        groups: dict = {}
        for it in items:
            sim_cfg = dataclasses.replace(it["cfg"], placement=None)
            groups.setdefault((it["nx"], it["ny"], sim_cfg), []).append(it)

        for (nx, ny, sim_cfg), members in groups.items():
            wants = schedulers.get(sim_cfg.scheduler).wants_criticality_order
            pad_lmax = not _latency_depends_on_words([sim_cfg])
            lmax, emax = shape_class(
                [(it["graph"], it["node_pe"]) for it in members], nx, ny)
            for it in members:
                spec = it["cfg"].placement
                gm = uniform_graph_memories(
                    it["graph"], nx, ny, [it["node_pe"]],
                    criticality_order=wants, metric=spec.metric,
                    pad_lmax=pad_lmax, min_lmax=lmax, min_emax=emax)[0]
                res = _simulate_batch(gm, [sim_cfg])[0]
                self.counters["simulations"] += 1
                it["cycles"] = int(res.cycles)
                it["stats"] = _result_stats(res)

    # -- the front door -----------------------------------------------------

    def run_batch(self, queries) -> list[QueryResult]:
        """Answer a batch of queries; order-preserving.

        Repeat keys — against the cache or within the batch — are answered
        exactly once; every duplicate serves from the first resolution with
        zero additional simulations and bit-exact integers.
        """
        queries = list(queries)
        self.counters["queries"] += len(queries)
        plans = []
        for q in queries:
            cfg = effective_config(q)
            digest = graph_digest(q.graph)
            key = query_key(q.graph, q.nx, q.ny, cfg, q.objective)
            plans.append({"query": q, "cfg": cfg, "digest": digest,
                          "key": key})

        resolved: dict[int, CachedResult] = {}
        fresh: dict[int, bool] = {}
        work: list[dict] = []
        for p in plans:
            key = p["key"]
            if key in resolved or key in fresh:
                continue  # within-batch duplicate: first occurrence answers
            entry = self.cache.get(key)
            if entry is not None:
                resolved[key] = entry
                continue
            fresh[key] = True
            q = p["query"]
            work.append({"key": key, "graph": q.graph, "nx": q.nx,
                         "ny": q.ny, "objective": q.objective,
                         "cfg": p["cfg"], "digest": p["digest"]})

        if work:
            self._resolve_placements(work)
            sim_items = [it for it in work if it["objective"] == "cycles"]
            if sim_items:
                self._simulate(sim_items)
            for it in work:
                if it["objective"] == "cost" and it["cost"] is None:
                    it["cost"] = self._model_cost(it)
                entry = CachedResult(
                    key=it["key"],
                    node_pe=np.asarray(it["node_pe"], dtype=np.int32),
                    objective=it["objective"],
                    cycles=it.get("cycles"),
                    cost=it.get("cost"),
                    stats=it.get("stats", {}))
                self.cache.put(it["key"], entry)
                resolved[it["key"]] = entry

        out = []
        for p in plans:
            e = resolved[p["key"]]
            out.append(QueryResult(
                key=e.key, node_pe=e.node_pe, objective=e.objective,
                cycles=e.cycles, cost=e.cost, stats=dict(e.stats),
                cached=p["key"] not in fresh))
        return out

    def query(self, q: PlacementQuery) -> QueryResult:
        """Answer one query (a batch of one)."""
        return self.run_batch([q])[0]

    def report(self) -> dict:
        """Telemetry-style counters: cache + execution, all exact ints."""
        rep = {f"cache_{k}": v for k, v in self.cache.report().items()}
        rep.update(self.counters)
        return rep
