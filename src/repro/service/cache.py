"""Content-addressed result cache for the placement service.

Extends the ``experiments/graph_cache/`` idea (build-once artifacts,
atomically published, addressed by a name that encodes every input) from
graphs to *results*: a cache entry holds the resolved placement plus the
bit-exact simulated cycle count and stat counters for one
:func:`repro.service.hashing.query_key`. Because the whole pipeline is
bit-deterministic, serving an entry is indistinguishable from re-running
the query — zero simulations, same integers.

In-memory the cache is a bounded LRU; pass ``directory=`` (or set
``$REPRO_SERVICE_CACHE``) to also persist entries as ``.npz`` files next to
the graph cache, using the same unique-tempfile + ``os.replace`` publish
idiom as :func:`repro.core.workloads.save_graph`. Counters (hits / misses /
evictions / disk hits) surface through :meth:`ResultCache.report`, mirroring
the ``repro.telemetry`` report style.
"""
from __future__ import annotations

import dataclasses
import json
import os
from collections import OrderedDict

import numpy as np


def service_cache_dir() -> str:
    """``$REPRO_SERVICE_CACHE`` or ``./experiments/service_cache``."""
    return os.environ.get(
        "REPRO_SERVICE_CACHE",
        os.path.join(os.getcwd(), "experiments", "service_cache"))


@dataclasses.dataclass(frozen=True, eq=False)
class CachedResult:
    """One answered query: placement + bit-exact result integers."""

    key: int                    # canonical query key (hashing.query_key)
    node_pe: np.ndarray         # [N] int32 node -> PE
    objective: str              # "cycles" | "cost"
    cycles: int | None          # simulated cycles (None for cost-only)
    cost: int | None            # integer placement-model cost (None = n/a)
    stats: dict                 # int stat counters from the SimResult


def _entry_path(directory: str, key: int) -> str:
    # Zero-padded unsigned hex so filenames are fixed-width and sortable.
    return os.path.join(directory, f"q{key & 0xFFFFFFFFFFFFFFFF:016x}.npz")


def _save_entry(path: str, entry: CachedResult) -> None:
    import tempfile

    os.makedirs(os.path.dirname(path), exist_ok=True)
    meta = {"key": int(entry.key), "objective": entry.objective,
            "cycles": entry.cycles, "cost": entry.cost,
            "stats": {k: int(v) for k, v in entry.stats.items()}}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, node_pe=entry.node_pe,
                                meta=np.str_(json.dumps(meta)))
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _load_entry(path: str) -> CachedResult:
    with np.load(path) as z:
        meta = json.loads(str(z["meta"]))
        return CachedResult(
            key=int(meta["key"]), node_pe=z["node_pe"].astype(np.int32),
            objective=meta["objective"], cycles=meta["cycles"],
            cost=meta["cost"],
            stats={k: int(v) for k, v in meta["stats"].items()})


class ResultCache:
    """Bounded LRU of :class:`CachedResult`, optionally disk-backed."""

    def __init__(self, capacity: int = 4096,
                 directory: str | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.directory = directory
        self._mem: OrderedDict[int, CachedResult] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.disk_hits = 0

    def __len__(self) -> int:
        return len(self._mem)

    def get(self, key: int) -> CachedResult | None:
        entry = self._mem.get(key)
        if entry is not None:
            self._mem.move_to_end(key)
            self.hits += 1
            return entry
        if self.directory is not None:
            path = _entry_path(self.directory, key)
            if os.path.exists(path):
                entry = _load_entry(path)
                self.disk_hits += 1
                self.hits += 1
                self._admit(key, entry)
                return entry
        self.misses += 1
        return None

    def put(self, key: int, entry: CachedResult) -> None:
        self._admit(key, entry)
        if self.directory is not None:
            _save_entry(_entry_path(self.directory, key), entry)

    def _admit(self, key: int, entry: CachedResult) -> None:
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1

    def report(self) -> dict:
        """Telemetry-style counter summary (all exact integers)."""
        lookups = self.hits + self.misses
        return {
            "entries": len(self._mem),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "disk_hits": self.disk_hits,
            "hit_rate": round(self.hits / lookups, 4) if lookups else 0.0,
        }
