"""Design-space explorer: tracked Pareto frontiers over the overlay config.

Replaces ``benchmarks/hillclimb.py``'s greedy coordinate descent with an
exhaustive sweep over a small named space — (scheduler, eject_policy, grid,
placement) — answered through the :class:`~repro.service.service
.PlacementService`, so repeated exploration of the same graph is nearly
free (every point is one service query: cached, batched, amortized).

Coordinate descent walks ONE path and returns one config; the explorer
returns the whole cycles-vs-area trade-off: every non-dominated
(simulated cycles, PE count) point. Because each point's cycle count is
bit-deterministic, the frontier is too — it is CI-gated in the BENCH
``service`` section exactly like the 48 tracked engine cycle counts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

#: Default explorer space: every axis the ISSUE/ROADMAP names. Grids are
#: (nx, ny); placement entries are strategy names or PlacementSpecs.
DEFAULT_SPACE = {
    "scheduler": ("ooo", "inorder"),
    "eject_policy": ("n_first", "priority"),
    "grid": ((4, 4), (8, 8)),
    "placement": ("identity", "anneal"),
}


def pareto_front(points: Sequence[dict],
                 objectives: tuple[str, str] = ("cycles", "num_pes")) -> list:
    """Non-dominated subset of ``points``, both objectives minimized.

    Deterministic: points sort by (objective tuple, name) before the scan,
    so ties always resolve the same way.
    """
    o1, o2 = objectives
    ordered = sorted(points, key=lambda p: (p[o2], p[o1], p["name"]))
    front: list = []
    best = None
    for p in ordered:  # ascending o2: keep strictly improving o1
        if best is None or p[o1] < best:
            front.append(p)
            best = p[o1]
    return front


def explore(graph, *, space: dict | None = None, budget: int | None = 4096,
            max_cycles: int = 4_000_000, service=None) -> dict:
    """Sweep the config space and return the (cycles, num_pes) frontier.

    Args:
      graph: a :class:`~repro.core.graph.DataflowGraph`.
      space: axes to sweep (defaults to :data:`DEFAULT_SPACE`; give a dict
        with any subset of its keys to narrow an axis).
      budget: annealer proposal budget per search placement (see
        :class:`~repro.service.service.PlacementQuery`).
      max_cycles: per-point simulation budget.
      service: a :class:`~repro.service.service.PlacementService` to answer
        through (shares its cache/surrogates with the rest of a stream);
        ``None`` builds a private one.

    Returns a machine-readable record: ``points`` (every swept combo with
    its bit-exact cycle count), ``frontier`` (the Pareto subset), and the
    service ``report`` counters.
    """
    from ..core.overlay import OverlayConfig
    from .service import PlacementQuery, PlacementService

    space = {**DEFAULT_SPACE, **(space or {})}
    service = service or PlacementService()

    combos = []
    for sched in space["scheduler"]:
        for policy in space["eject_policy"]:
            for nx, ny in space["grid"]:
                for placement in space["placement"]:
                    combos.append((sched, policy, int(nx), int(ny),
                                   placement))

    queries = [
        PlacementQuery(
            graph=graph, nx=nx, ny=ny, objective="cycles", budget=budget,
            cfg=OverlayConfig(scheduler=sched, eject_policy=policy,
                              max_cycles=max_cycles, placement=placement))
        for sched, policy, nx, ny, placement in combos]
    results = service.run_batch(queries)

    points = []
    for (sched, policy, nx, ny, placement), res in zip(combos, results):
        name = (f"{sched}__{policy}__{nx}x{ny}__"
                f"{_placement_name(placement)}")
        points.append({
            "name": name,
            "scheduler": sched,
            "eject_policy": policy,
            "grid": [nx, ny],
            "num_pes": nx * ny,
            "placement": _placement_name(placement),
            "cycles": int(res.cycles),
            "cached": bool(res.cached),
            "key": int(res.key),
        })
    return {
        "space": {k: [str(v) for v in vs] for k, vs in space.items()},
        "points": points,
        "frontier": pareto_front(points),
        "report": service.report(),
    }


def _placement_name(placement) -> str:
    if isinstance(placement, str):
        return placement
    if placement is None:
        return "identity"
    if dataclasses.is_dataclass(placement):
        return placement.strategy
    return str(placement)
