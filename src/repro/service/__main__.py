"""Service CLI: ``python -m repro.service [--smoke]``.

Default mode replays the deterministic ``workloads.service_stream`` through
a fresh :class:`~repro.service.service.PlacementService` and prints the
amortization story: per-query hit/miss, then the cache + execution
counters.

``--smoke`` is the CI tier-1 gate. On a small stream it asserts the
service contract end to end:

  * repeat queries answer from the content-hash cache with ZERO additional
    simulations and bit-exact cycles (counter-asserted);
  * a batched multi-query anneal fan-out returns row-for-row the same
    placements and cycle counts as solo queries;
  * the design-space explorer's Pareto frontier is deterministic under
    replay.

Exits non-zero on any violation.
"""
from __future__ import annotations

import sys


def smoke() -> None:
    import numpy as np

    from repro.core import workloads as wl
    from repro.core.overlay import OverlayConfig
    from repro.service import PlacementQuery, PlacementService, explore

    cfg = OverlayConfig(placement="anneal", max_cycles=200_000)
    stream = wl.service_stream(n_queries=8, distinct=3, seed=0)

    # 1. Repeats are free: zero extra simulations, bit-exact integers.
    svc = PlacementService()
    answers = {}
    for name, g in stream:
        sims_before = svc.counters["simulations"]
        r = svc.query(PlacementQuery(graph=g, nx=4, ny=4, budget=2048,
                                     cfg=cfg))
        if name in answers:
            first = answers[name]
            assert r.cached, f"{name}: repeat missed the cache"
            assert svc.counters["simulations"] == sims_before, (
                f"{name}: cache hit ran a simulation")
            assert r.cycles == first.cycles, (name, r.cycles, first.cycles)
            assert np.array_equal(r.node_pe, first.node_pe), name
            assert r.stats == first.stats, name
        else:
            assert not r.cached and r.cycles is not None, name
            answers[name] = r
    rep = svc.report()
    assert rep["cache_hits"] == len(stream) - len(answers), rep
    assert rep["simulations"] == len(answers), rep
    print(f"service_smoke_stream,0.0,hit_rate={rep['cache_hit_rate']}")

    # 2. Batched anneal fan-out == solo queries, row for row.
    g = stream[0][1]
    seeds = (0, 1, 2)
    mk = lambda s: PlacementQuery(
        graph=g, nx=4, ny=4, budget=2048,
        cfg=OverlayConfig(placement=wl_spec(s), max_cycles=200_000))
    batched = PlacementService().run_batch([mk(s) for s in seeds])
    solo = [PlacementService().query(mk(s)) for s in seeds]
    for s, b, r in zip(seeds, batched, solo):
        assert np.array_equal(b.node_pe, r.node_pe), f"seed {s}"
        assert b.cycles == r.cycles, (s, b.cycles, r.cycles)
    print(f"service_smoke_batch,0.0,rows={len(seeds)}")

    # 3. Frontier determinism under replay.
    space = {"grid": ((2, 2), (4, 4)), "placement": ("identity", "anneal")}
    rec1 = explore(g, space=space, budget=2048, max_cycles=200_000)
    rec2 = explore(g, space=space, budget=2048, max_cycles=200_000)
    assert rec1["frontier"] == rec2["frontier"], "frontier not deterministic"
    assert rec1["points"] == rec2["points"], "points not deterministic"
    front = ",".join(f"{p['name']}={p['cycles']}" for p in rec1["frontier"])
    print(f"service_smoke_frontier,0.0,{front}")
    print("SERVICE_SMOKE_OK")


def wl_spec(seed: int):
    from repro.place import PlacementSpec

    return PlacementSpec(strategy="anneal", seed=seed)


def demo() -> None:
    from repro.core.overlay import OverlayConfig
    from repro.core.workloads import service_stream
    from repro.service import PlacementQuery, PlacementService

    svc = PlacementService()
    cfg = OverlayConfig(placement="anneal", max_cycles=1_000_000)
    for name, g in service_stream(n_queries=16, distinct=4, seed=0):
        r = svc.query(PlacementQuery(graph=g, nx=4, ny=4, budget=4096,
                                     cfg=cfg))
        tag = "hit " if r.cached else "miss"
        print(f"{tag} {name}: {r.cycles} cycles (key {r.key:#x})")
    for k, v in sorted(svc.report().items()):
        print(f"  {k} = {v}")


def main(argv: list[str]) -> int:
    if "--smoke" in argv:
        smoke()
        return 0
    if not [a for a in argv if a.startswith("-")]:
        demo()
        return 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
