"""repro.service — batched placement-as-a-service with content-hash caching.

The fleet-scale spelling of the paper's one-time static placement pass:
answer a *stream* of (graph, grid, objective, budget) queries, where repeat
graphs are free and search cost amortizes across the stream.

  * :class:`PlacementQuery` / :class:`QueryResult` — the query schema;
  * :class:`PlacementService` — the front door: content-hash result cache
    (:class:`ResultCache`, :func:`query_key`), vmapped multi-query anneal
    fan-out, one shared surrogate per (graph, grid) family, shape-class
    batched simulation;
  * :func:`explore` / :func:`pareto_front` — the design-space explorer
    producing tracked Pareto frontiers over (scheduler, eject_policy,
    grid, placement);
  * ``python -m repro.service --smoke`` — the tier-1 CI gate.

Everything stays bit-deterministic, so cached results and frontier points
are CI-gated in the BENCH ``service`` section like every other tracked
cycle count. See docs/service.md.
"""
from .cache import CachedResult, ResultCache, service_cache_dir  # noqa: F401
from .explore import DEFAULT_SPACE, explore, pareto_front  # noqa: F401
from .hashing import (  # noqa: F401
    config_token,
    graph_digest,
    query_digest,
    query_key,
)
from .service import (  # noqa: F401
    PlacementQuery,
    PlacementService,
    QueryResult,
    effective_config,
)
