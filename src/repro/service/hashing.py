"""Process-stable content-hash keys for the placement service cache.

A query is identified by what it *means*, not by object identity: the raw
:class:`~repro.core.graph.DataflowGraph` tables, the PE grid, the canonical
:class:`~repro.place.spec.PlacementSpec`, the model knobs of the
:class:`~repro.core.overlay.OverlayConfig`, and the query objective, all fed
through BLAKE2b. Two processes (or two CI runs) that build the same graph
get the same key — Python's randomized ``hash()`` is never involved, so keys
survive ``PYTHONHASHSEED`` and can name on-disk cache entries.

Execution-only knobs are deliberately EXCLUDED from the key: ``engine`` and
``check_every`` pick *how* a chunk of cycles executes, never what it
computes (all engines are bit-identical, the repo-wide contract), so a
result simulated under ``engine="megakernel"`` legitimately serves a later
``engine="jnp"`` query for the same model.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

#: OverlayConfig fields that change simulation *semantics* (cycle counts).
#: ``engine`` / ``check_every`` are execution strategy and excluded — see
#: the module docstring.
MODEL_KNOBS = ("scheduler", "select_latency", "eject_capacity", "max_cycles",
               "eject_policy", "placement", "telemetry")


def _update_array(h, tag: str, a) -> None:
    a = np.ascontiguousarray(a)
    h.update(tag.encode())
    h.update(str(a.dtype).encode())
    h.update(np.asarray(a.shape, dtype=np.int64).tobytes())
    h.update(a.tobytes())


def encode_value(v) -> str:
    """Canonical, process-stable string form of a config value.

    Dataclasses (PlacementSpec, AnnealConfig, TelemetrySpec, ...) encode as
    ``TypeName(field=..., ...)`` with fields sorted by name, recursively —
    declaration-order or dict-iteration accidents can't move the key.
    """
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        d = dataclasses.asdict(v)
        inner = ",".join(f"{k}={encode_value(d[k])}" for k in sorted(d))
        return f"{type(v).__name__}({inner})"
    if isinstance(v, dict):
        return "{" + ",".join(
            f"{k}:{encode_value(v[k])}" for k in sorted(v)) + "}"
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(encode_value(x) for x in v) + "]"
    if isinstance(v, float):
        return repr(float(v))
    return repr(v)


def config_token(cfg) -> str:
    """Canonical encoding of an OverlayConfig's model knobs.

    ``cfg.placement`` is already a canonical ``PlacementSpec``
    (``OverlayConfig.__post_init__`` runs every spelling through
    :func:`repro.place.spec.resolve`), so ``placement="anneal"`` and
    ``placement=PlacementSpec(strategy="anneal")`` produce one token.
    """
    return ";".join(f"{k}={encode_value(getattr(cfg, k))}"
                    for k in MODEL_KNOBS)


def graph_digest(g) -> bytes:
    """16-byte BLAKE2b digest of the DataflowGraph tables."""
    h = hashlib.blake2b(digest_size=16)
    _update_array(h, "opcode", g.opcode)
    _update_array(h, "fanout_ptr", g.fanout_ptr)
    _update_array(h, "fanout_dst", g.fanout_dst)
    _update_array(h, "fanout_slot", g.fanout_slot)
    _update_array(h, "initial_values", g.initial_values)
    return h.digest()


def query_digest(g, nx: int, ny: int, cfg, objective: str = "cycles") -> bytes:
    """16-byte digest of (graph tables, grid, model knobs, objective)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_digest(g))
    h.update(f"grid={int(nx)}x{int(ny)};obj={objective};".encode())
    h.update(config_token(cfg).encode())
    return h.digest()


def query_key(g, nx: int, ny: int, cfg, objective: str = "cycles") -> int:
    """Canonical int64 cache key (signed, from the digest's first 8 bytes)."""
    d = query_digest(g, nx, ny, cfg, objective)
    return int(np.frombuffer(d[:8], dtype="<i8")[0])
