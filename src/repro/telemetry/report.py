"""Structured telemetry summaries — the numbers behind docs/telemetry.md.

Every count in the report is an exact integer taken from the bit-
deterministic traces (CI gates them in the BENCH ``telemetry`` section);
utilization ratios are derived floats. Sections appear only when the
corresponding :class:`~repro.telemetry.spec.TelemetrySpec` group was on.
"""
from __future__ import annotations

import numpy as np


def _link_rows(res) -> list[tuple[str, int]]:
    """(label, busy-cycles) for every E and S link, by router coordinate."""
    rows = []
    for leaf, tag in (("link_e", "E"), ("link_s", "S")):
        busy = res.traces[leaf].sum(axis=0)
        for x in range(res.nx):
            for y in range(res.ny):
                rows.append((f"{tag}@{x},{y}", int(busy[x, y])))
    return rows


def build_report(res, top_k: int = 5) -> dict:
    """Summary dict for one simulation's traces.

    Schema (sections keyed by enabled spec groups)::

        cycles, grid
        links:  busy_max, util_p50, util_p95, util_max, top[k],
                defl_noc, defl_eject
        pe:     busy_total, busy_max, occ_total, util_mean
        sched:  picks, pick_pos_mean, ready_depth_mean
        stalls: no_ready, inject_blocked, select_wait, eject_deflected
    """
    cycles = max(1, int(res.cycles))
    rep: dict = {"cycles": int(res.cycles), "grid": [res.nx, res.ny]}

    if "link_e" in res.traces:
        rows = _link_rows(res)
        busy = np.array([b for _, b in rows], dtype=np.int64)
        util = busy / cycles
        hot = sorted(rows, key=lambda r: (-r[1], r[0]))[:top_k]
        rep["links"] = {
            "busy_max": int(busy.max()),
            "util_p50": round(float(np.percentile(util, 50)), 4),
            "util_p95": round(float(np.percentile(util, 95)), 4),
            "util_max": round(float(util.max()), 4),
            "top": [{"link": label, "busy": b,
                     "util": round(b / cycles, 4)} for label, b in hot],
            "defl_noc": int(res.traces["defl_noc"].sum()),
            "defl_eject": int(res.traces["defl_eject"].sum()),
        }
    if "pe_busy" in res.traces:
        busy = res.traces["pe_busy"].sum(axis=0)
        rep["pe"] = {
            "busy_total": int(busy.sum()),
            "busy_max": int(busy.max()),
            "occ_total": int(res.traces["pe_occ"].sum()),
            "util_mean": round(float(busy.mean()) / cycles, 4),
        }
    if "picks" in res.traces:
        picks = int(res.traces["picks"].sum())
        rep["sched"] = {
            "picks": picks,
            # Mean slot index of committed picks: with criticality-ordered
            # memory, lower == the scheduler is finding critical work.
            "pick_pos_mean": round(
                int(res.traces["pick_pos"].sum()) / max(1, picks), 2),
            "ready_depth_mean": round(
                int(res.traces["ready_depth"].sum())
                / (cycles * res.nx * res.ny), 3),
        }
    if "stall_no_ready" in res.traces:
        rep["stalls"] = {
            # Per-PE-cycle attribution of why work didn't advance:
            "no_ready": int(res.traces["stall_no_ready"].sum()),
            "inject_blocked": int(res.traces["stall_inject"].sum()),
            "select_wait": int(res.traces["stall_sel_wait"].sum()),
            # eject losers circulate the ring — the NoC-side stall.
            "eject_deflected": int(res.traces["defl_eject"].sum())
            if "defl_eject" in res.traces else None,
        }
    return rep
