"""Chrome-trace / Perfetto JSON export of telemetry traces.

Emits the Trace Event Format (the JSON Perfetto UI and chrome://tracing
both load): one counter track (``"ph": "C"``) per PE, per link, per router
deflection/eject port, plus global wavefront and ready-depth tracks, with
one sample per time bucket. Timestamps are in "microseconds" 1:1 with
simulated cycles, so the UI's time axis reads directly as cycles.

Track inventory (distinct counter names; asserted in tests):

    pe    -> nx*ny  ``pe@x,y``       {busy, occupied}   + 1 ``wavefront``
    links -> 2*nx*ny ``link_{E,S}@x,y`` {busy}
             + nx*ny ``deflect@x,y``    {noc, eject}
    eject -> nx*ny  ``eject@x,y``    {grants}
    sched -> 1      ``ready_depth``  {total}
"""
from __future__ import annotations

import json


def track_count(spec, nx: int, ny: int) -> int:
    """Number of distinct counter tracks :func:`export` emits."""
    n = 0
    if spec.pe:
        n += nx * ny + 1          # pe@x,y + wavefront
    if spec.links:
        n += 3 * nx * ny          # link_E, link_S, deflect
    if spec.eject:
        n += nx * ny
    if spec.sched:
        n += 1                    # global ready_depth
    return n


def export(res, path: str | None = None) -> dict:
    """Build (and optionally write) the Chrome-trace JSON for ``res``."""
    spec = res.spec
    nx, ny = res.nx, res.ny
    used = res.used_buckets
    ev: list[dict] = []

    for pid, name in ((0, "PEs"), (1, "NoC links"), (2, "scheduler")):
        ev.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                   "args": {"name": f"overlay {name}"}})

    def counter(pid, name, b, args):
        ev.append({"ph": "C", "pid": pid, "tid": 0, "name": name,
                   "ts": b * spec.bucket_cycles, "args": args})

    t = res.traces
    wave = res.wavefront() if spec.pe else None
    for b in range(used):
        if spec.pe:
            counter(0, "wavefront", b, {"fired_cum": int(wave[b])})
        if spec.sched:
            counter(2, "ready_depth", b,
                    {"total": int(t["ready_depth"][b].sum())})
        for x in range(nx):
            for y in range(ny):
                if spec.pe:
                    counter(0, f"pe@{x},{y}", b,
                            {"busy": int(t["pe_busy"][b, x, y]),
                             "occupied": int(t["pe_occ"][b, x, y])})
                if spec.links:
                    counter(1, f"link_E@{x},{y}", b,
                            {"busy": int(t["link_e"][b, x, y])})
                    counter(1, f"link_S@{x},{y}", b,
                            {"busy": int(t["link_s"][b, x, y])})
                    counter(1, f"deflect@{x},{y}", b,
                            {"noc": int(t["defl_noc"][b, x, y]),
                             "eject": int(t["defl_eject"][b, x, y])})
                if spec.eject:
                    counter(1, f"eject@{x},{y}", b,
                            {"grants": int(t["eject_grant"][b, x, y])})

    trace = {
        "traceEvents": ev,
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.telemetry",
            "cycles": int(res.cycles),
            "grid": f"{nx}x{ny}",
            "bucket_cycles": spec.bucket_cycles,
        },
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace
