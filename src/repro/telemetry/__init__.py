"""Cycle-resolved, bit-deterministic tracing of the overlay (docs/telemetry.md).

The paper's headline numbers are aggregates; this package instruments *why*
— per-PE occupancy, per-link Hoplite utilization, deflections by cause,
eject-port contention, scheduler ready-set depth, and stall attribution —
without perturbing the model. Opt in via::

    import repro
    from repro.telemetry import TelemetrySpec
    r = repro.run(gm, OverlayConfig(telemetry=TelemetrySpec()))
    r.telemetry.report()                      # p50/p95 link util, stalls, ...
    r.telemetry.export_perfetto("trace.json") # open in ui.perfetto.dev

Traces accumulate as integer tensors *inside* the jitted cycle loop
(:mod:`.trace`), ride the state pytree through all four engines — solo,
batched, sharded, batched-sharded — and through the chunk repair and the
fused megakernel, and are bit-identical across every engine and
``check_every``. ``telemetry=None`` (the default) compiles to exactly the
untraced program. ``python -m repro.telemetry`` runs a cached fig1 workload
and renders an ASCII heatmap + a Perfetto trace artifact.
"""
from .result import TelemetryResult
from .spec import TelemetrySpec

__all__ = ["TelemetrySpec", "TelemetryResult"]
