"""Host-side telemetry container: numpy traces + report/export helpers."""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .spec import TelemetrySpec

#: glyph ramp for the ASCII heatmap, dimmest to hottest.
_RAMP = " .:-=+*#%@"


@dataclasses.dataclass
class TelemetryResult:
    """Traces of ONE simulation, unpacked to numpy by ``SimResult``.

    ``traces`` maps leaf name (see :mod:`repro.telemetry.trace`) to an int32
    array — ``[buckets, nx, ny]`` for bucketed traces, ``[nx, ny]`` for
    per-PE totals. ``cycles`` is the simulated cycle count, which bounds the
    buckets that actually saw traffic."""

    spec: TelemetrySpec
    traces: dict[str, np.ndarray]
    cycles: int
    nx: int
    ny: int

    @property
    def used_buckets(self) -> int:
        """Buckets covering the simulated cycle range (>= 1)."""
        return max(1, min(self.spec.buckets,
                          math.ceil(self.cycles / self.spec.bucket_cycles)))

    def wavefront(self) -> np.ndarray:
        """[used_buckets] cumulative node fires — the wavefront-progress
        curve (requires the ``pe`` trace group)."""
        fires = self.traces["pe_busy"].sum(axis=(-2, -1))
        return np.cumsum(fires)[: self.used_buckets]

    def report(self, top_k: int = 5) -> dict:
        """Structured summary: p50/p95/max link utilization, top-k hot
        links, stall-cycle attribution. See :func:`repro.telemetry.report
        .build_report` for the schema."""
        from .report import build_report

        return build_report(self, top_k=top_k)

    def export_perfetto(self, path: str | None = None) -> dict:
        """Chrome-trace/Perfetto JSON (counter tracks per PE / link /
        router); written to ``path`` when given, returned either way."""
        from .perfetto import export

        return export(self, path=path)

    def ascii_heatmap(self, leaf: str = "pe_busy") -> str:
        """Terminal heatmap of a trace leaf summed over time (x down,
        y across) — the CLI's at-a-glance hot-spot view."""
        a = self.traces[leaf]
        grid = a.sum(axis=0) if a.ndim == 3 else a
        peak = int(grid.max())
        lines = [f"{leaf} per PE (peak {peak}, {self.nx}x{self.ny} grid)"]
        for x in range(self.nx):
            row = ""
            for y in range(self.ny):
                lvl = 0 if peak == 0 else int(
                    grid[x, y] * (len(_RAMP) - 1) / peak)
                row += _RAMP[lvl] * 2
            lines.append(row)
        return "\n".join(lines)
