"""TelemetrySpec — the static, hashable knob that turns tracing on.

The spec rides :class:`repro.core.overlay.OverlayConfig` (a static jit
argument), so it must be a frozen, hashable dataclass whose fields fully
determine the traced state shapes — the same contract as
:mod:`repro.place.spec`. Turning any group on adds integer trace leaves
under ``state["telem"]``; ``telemetry=None`` adds nothing and the traced
program is bit-identical to the untraced one.

Memory cost (int32, per simulation; the batched engine multiplies by the
config-batch size)::

    bucketed   buckets * nx * ny * 4 bytes  per bucketed leaf
               (pe: 2 leaves, links: 4, eject: 1, sched: 1)
    totals     nx * ny * 4 bytes            per total leaf
               (sched: 2, stalls: 3)

Per-cycle resolution is just ``bucket_cycles=1`` with ``buckets`` >= the
expected cycle count (:meth:`TelemetrySpec.per_cycle`); the default
64 x 32 bucketing covers 2048 cycles at ~100KB for a 16x16 grid, and
cycles past the horizon clamp into the last bucket so trace sums always
equal the scalar counters.
"""
from __future__ import annotations

import dataclasses

#: bucketed [buckets, nx, ny] leaves, by spec group.
BUCKETED_LEAVES = {
    "pe": ("pe_busy", "pe_occ"),
    "links": ("link_e", "link_s", "defl_noc", "defl_eject"),
    "eject": ("eject_grant",),
    "sched": ("ready_depth",),
}
#: per-PE total [nx, ny] leaves, by spec group.
TOTAL_LEAVES = {
    "sched": ("pick_pos", "picks"),
    "stalls": ("stall_no_ready", "stall_inject", "stall_sel_wait"),
}
GROUPS = ("pe", "links", "eject", "sched", "stalls")


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Which trace groups to record, and at what time resolution.

    ``buckets`` x ``bucket_cycles`` is the trace horizon in cycles; a cycle
    past it lands in the last bucket (clamped, never dropped). Groups:

      * ``pe``    — per-PE fires (``pe_busy``, sums to ``busy_cycles``) and
        fanout-drain occupancy (``pe_occ``) per bucket;
      * ``links`` — per-router E/S link utilization plus the deflection
        split by cause (``defl_noc`` sums to ``noc_deflections``,
        ``defl_eject`` to ``eject_deflections``);
      * ``eject`` — eject-port grants per router (sums to ``delivered``);
        the loser side of the contention is ``defl_eject``;
      * ``sched`` — ready-set depth per bucket (via the
        ``Scheduler.ready_depth`` protocol hook) + total pick count and
        summed pick slot position per PE;
      * ``stalls`` — per-PE stall attribution totals: idle with nothing
        ready, injection blocked by the NoC, pick serialized behind the
        exposed select latency.
    """

    buckets: int = 64
    bucket_cycles: int = 32
    pe: bool = True
    links: bool = True
    eject: bool = True
    sched: bool = True
    stalls: bool = True

    def __post_init__(self):
        if self.buckets < 1:
            raise ValueError(f"buckets must be >= 1, got {self.buckets}")
        if self.bucket_cycles < 1:
            raise ValueError(
                f"bucket_cycles must be >= 1, got {self.bucket_cycles}")
        if not any(getattr(self, g) for g in GROUPS):
            raise ValueError(
                "TelemetrySpec with every trace group off records nothing; "
                "pass telemetry=None instead")

    @classmethod
    def per_cycle(cls, max_cycles: int, **groups) -> "TelemetrySpec":
        """Cycle-resolved spec: one bucket per cycle up to ``max_cycles``."""
        return cls(buckets=int(max_cycles), bucket_cycles=1, **groups)

    @property
    def horizon(self) -> int:
        """Cycles covered before clamping into the last bucket."""
        return self.buckets * self.bucket_cycles

    def leaf_names(self) -> tuple[str, ...]:
        """Trace-leaf names this spec records, bucketed first."""
        names = [n for g in GROUPS if getattr(self, g)
                 for n in BUCKETED_LEAVES.get(g, ())]
        names += [n for g in GROUPS if getattr(self, g)
                  for n in TOTAL_LEAVES.get(g, ())]
        return tuple(names)

    def memory_bytes(self, nx: int, ny: int) -> int:
        """int32 trace footprint for one simulation on an nx x ny grid."""
        n_bucketed = sum(len(BUCKETED_LEAVES.get(g, ()))
                         for g in GROUPS if getattr(self, g))
        n_total = sum(len(TOTAL_LEAVES.get(g, ()))
                      for g in GROUPS if getattr(self, g))
        return 4 * nx * ny * (self.buckets * n_bucketed + n_total)
