"""In-engine trace accumulation — the jnp half of :mod:`repro.telemetry`.

:func:`init` builds the zeroed trace leaves that ride the simulation state
pytree under ``state["telem"]``; :func:`accumulate` is called once per cycle
by ``overlay.make_cycle_fn`` with signals the model already computed. Both
are pure jnp functions of [nx, ny]-local arrays, so they work unchanged
under ``jax.vmap`` (the batched sweep engine), ``shard_map`` (leaves keep
the grid dims as their LAST TWO axes — one tiled all_gather per mesh axis
reassembles the global trace, see ``distributed._gather_telem``) and inside
the megakernel's ``pallas_call`` (leaves flatten to kernel refs like any
other state leaf).

Bit-determinism contract: every increment is integer, PE-local, and —
except ``stall_no_ready``, repaired by ``overlay.repair_telemetry`` — zero
at the completed-overlay fixed point, so the guard-free chunk engines can
over-simulate past completion without drifting any trace. This module must
not import :mod:`repro.core.overlay` (overlay lazily imports it).
"""
from __future__ import annotations

import jax.numpy as jnp

from .spec import TelemetrySpec


def init(spec: TelemetrySpec, nx: int, ny: int) -> dict:
    """Zeroed trace leaves for one simulation on an nx x ny PE grid."""
    zb = lambda: jnp.zeros((spec.buckets, nx, ny), jnp.int32)
    z2 = lambda: jnp.zeros((nx, ny), jnp.int32)
    t: dict = {}
    if spec.pe:
        t["pe_busy"] = zb()       # node fires          (sums to busy_cycles)
        t["pe_occ"] = zb()        # fanout-drain-occupied cycles
    if spec.links:
        t["link_e"] = zb()        # E output register valid
        t["link_s"] = zb()        # S output register valid
        t["defl_noc"] = zb()      # route-contention    (sums to noc_deflections)
        t["defl_eject"] = zb()    # eject-port losers   (sums to eject_deflections)
    if spec.eject:
        t["eject_grant"] = zb()   # eject-port grants   (sums to delivered)
    if spec.sched:
        t["ready_depth"] = zb()   # queued-ready nodes, summed per bucket
        t["pick_pos"] = z2()      # summed selected slot index
        t["picks"] = z2()         # number of committed picks
    if spec.stalls:
        t["stall_no_ready"] = z2()   # idle, nothing ready (overshoot-repaired)
        t["stall_inject"] = z2()     # injection offered but NoC-blocked
        t["stall_sel_wait"] = z2()   # pick held behind exposed select latency
    return t


def accumulate(spec: TelemetrySpec, t: dict, *, cycle, fired, occupied,
               link_e_busy, link_s_busy, defl_noc, defl_eject, eject_grant,
               ready_depth, sel, cand, no_ready, inj_blocked,
               sel_waiting) -> dict:
    """One cycle of trace increments. All inputs are [nx, ny] signals the
    cycle body already computed (``cycle`` is the pre-increment cycle
    counter, used as the bucket timestamp); clamping the bucket index keeps
    post-horizon cycles counted, so trace sums stay exactly equal to the
    scalar stat counters."""
    out = dict(t)
    b = jnp.minimum(cycle // spec.bucket_cycles, spec.buckets - 1)

    def bump(name, inc):
        out[name] = out[name].at[b].add(inc.astype(jnp.int32))

    if spec.pe:
        bump("pe_busy", fired)
        bump("pe_occ", occupied)
    if spec.links:
        bump("link_e", link_e_busy)
        bump("link_s", link_s_busy)
        bump("defl_noc", defl_noc)
        bump("defl_eject", defl_eject)
    if spec.eject:
        bump("eject_grant", eject_grant)
    if spec.sched:
        bump("ready_depth", ready_depth)
        out["pick_pos"] = out["pick_pos"] + jnp.where(sel, cand, 0)
        out["picks"] = out["picks"] + sel.astype(jnp.int32)
    if spec.stalls:
        out["stall_no_ready"] = out["stall_no_ready"] + no_ready.astype(jnp.int32)
        out["stall_inject"] = out["stall_inject"] + inj_blocked.astype(jnp.int32)
        out["stall_sel_wait"] = (out["stall_sel_wait"]
                                 + sel_waiting.astype(jnp.int32))
    return out
