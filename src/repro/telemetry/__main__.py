"""Telemetry CLI: ``python -m repro.telemetry [--smoke] [--out DIR]``.

Runs the fig1-family workload from the on-disk graph cache (CI pre-warms
it — see ``workloads.warm_cache``) with tracing on for ``ooo`` and
``inorder``, prints the ASCII PE-activity heatmap plus the stall-
attribution report, and writes one Perfetto/Chrome-trace JSON per policy
under ``--out`` (default ``experiments/telemetry/``) — load them at
https://ui.perfetto.dev or chrome://tracing.

``--smoke`` is the CI tier-1 gate: on a small graph it additionally
asserts the telemetry contract end to end — cycles unchanged with tracing
on, traces summing to the scalar stat counters, and the exported JSON
reloading with the exact expected counter-track count. Exits non-zero on
any violation.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _trace_path(out_dir: str, name: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, f"{name}.perfetto.json")


def smoke(out_dir: str) -> None:
    from repro.core import workloads as wl
    from repro.api import run
    from repro.core.overlay import OverlayConfig
    from repro.core.partition import build_graph_memory
    from repro.telemetry import TelemetrySpec
    from repro.telemetry.perfetto import track_count

    g = wl.layered_dag(5, 8, seed=3)
    gm = build_graph_memory(g, 2, 2, criticality_order=True)
    spec = TelemetrySpec(buckets=16, bucket_cycles=8)
    for sched in ("ooo", "inorder"):
        base = run(gm, OverlayConfig(scheduler=sched))
        r = run(gm, OverlayConfig(scheduler=sched, telemetry=spec))
        tel = r.telemetry
        assert r.done and r.cycles == base.cycles, (sched, r.cycles, base.cycles)
        assert int(tel.traces["pe_busy"].sum()) == r.busy_cycles
        assert int(tel.traces["defl_noc"].sum()) == r.noc_deflections
        assert int(tel.traces["defl_eject"].sum()) == r.eject_deflections
        assert int(tel.traces["eject_grant"].sum()) == r.delivered
        assert r.noc_deflections + r.eject_deflections == r.deflections

        path = _trace_path(out_dir, f"smoke_{sched}")
        tel.export_perfetto(path)
        with open(path) as f:
            loaded = json.load(f)
        tracks = {(e["pid"], e["name"]) for e in loaded["traceEvents"]
                  if e["ph"] == "C"}
        assert len(tracks) == track_count(spec, 2, 2), (
            len(tracks), track_count(spec, 2, 2))
        rep = tel.report()
        assert rep["stalls"]["no_ready"] >= 0 and rep["links"]["busy_max"] > 0
        print(f"telemetry_smoke_{sched},0.0,{r.cycles}")
    print("TELEMETRY_SMOKE_OK")


def fig1(out_dir: str) -> None:
    from repro.core import schedulers
    from repro.core import workloads as wl
    from repro.api import run
    from repro.core.overlay import OverlayConfig
    from repro.core.partition import build_graph_memory
    from repro.telemetry import TelemetrySpec

    name = wl.MEGAKERNEL_BENCH_GRAPHS[0]
    g = wl.cached_graph(name, lambda: wl.arrow_lu_graph(4, 10, 8, seed=3))
    spec = TelemetrySpec()
    for sched in ("ooo", "inorder"):
        gm = build_graph_memory(
            g, 16, 16,
            criticality_order=schedulers.get(sched).wants_criticality_order)
        t0 = time.time()
        r = run(gm, OverlayConfig(scheduler=sched, max_cycles=8_000_000,
                                       telemetry=spec))
        assert r.done, sched
        path = _trace_path(out_dir, f"fig1_{name}_{sched}")
        r.telemetry.export_perfetto(path)
        rep = r.telemetry.report()
        print(f"\n=== {sched}: {r.cycles} cycles on {name} "
              f"({round(time.time() - t0, 1)}s) ===")
        print(r.telemetry.ascii_heatmap("pe_busy"))
        print(f"links: p50 util {rep['links']['util_p50']}, "
              f"p95 {rep['links']['util_p95']}, max {rep['links']['util_max']}"
              f"; hot: " + ", ".join(
                  f"{t['link']}={t['busy']}" for t in rep["links"]["top"][:3]))
        print(f"stalls: {rep['stalls']}")
        print(f"sched: {rep['sched']}")
        print(f"trace: {path}")


def main(argv: list[str]) -> int:
    out_dir = os.environ.get(
        "REPRO_TELEMETRY_DIR",
        os.path.join(os.getcwd(), "experiments", "telemetry"))
    if "--out" in argv:
        out_dir = argv[argv.index("--out") + 1]
    if "--smoke" in argv:
        smoke(out_dir)
        return 0
    if "--fig1" in argv or not [a for a in argv if a.startswith("-")]:
        fig1(out_dir)
        return 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
