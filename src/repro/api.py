"""repro.run — the one front-door entry point for overlay simulation.

Historically the engines were four separate functions that differed only in
*how* the same cycle body executes, never in what it computes:

  =====================================  ==========================
  legacy entry point                     ``repro.run`` spelling
  =====================================  ==========================
  ``overlay.simulate``                   ``run(gm, cfg)``
  ``overlay.simulate_batch``             ``run(gm, batch=cfgs)``
  ``distributed.simulate_sharded``       ``run(gm, cfg, mesh=mesh)``
  ``distributed.simulate_batch_sharded`` ``run(gm, mesh=mesh, batch=cfgs)``
  =====================================  ==========================

``run`` keeps that bit-determinism contract: every path returns results
bit-identical to the legacy entry point it replaces (asserted in
``tests/test_service.py``; all 48 tracked BENCH cycle counts reproduce
through the dispatcher). The legacy four remain as thin
``DeprecationWarning`` wrappers around the same private implementations.
"""
from __future__ import annotations

from typing import Any, Sequence


def run(graph_or_gm, cfg=None, *, mesh=None, batch: Sequence | None = None,
        nx: int | None = None, ny: int | None = None) -> Any:
    """Simulate an overlay; the engine path is picked from the arguments.

    Args:
      graph_or_gm: a packed :class:`repro.core.partition.GraphMemory`, or a
        raw :class:`repro.core.graph.DataflowGraph` plus ``nx``/``ny`` (the
        graph is placed per ``cfg.placement`` — see :mod:`repro.place`).
      cfg: a single :class:`repro.core.overlay.OverlayConfig` (``None`` =
        defaults). Mutually exclusive with ``batch``.
      mesh: a :class:`jax.sharding.Mesh` with ``("data", "model")`` axes —
        shards the PE grid across devices (``nx`` divisible by the data
        axis, ``ny`` by the model axis).
      batch: a sequence of ``OverlayConfig`` — runs the whole sweep as ONE
        XLA program (vmapped cycle body) and returns a list of results,
        element-wise bit-identical to solo runs.
      nx, ny: PE grid, required only with a raw ``DataflowGraph``.

    Returns:
      :class:`repro.core.overlay.SimResult` (or a list of them with
      ``batch=``).
    """
    if batch is not None:
        if cfg is not None:
            raise ValueError(
                "repro.run: pass either cfg= (one config) or batch= "
                "(a config sweep), not both")
        batch = list(batch)
        if mesh is not None:
            from .core.distributed import _simulate_batch_sharded
            return _simulate_batch_sharded(graph_or_gm, mesh, batch,
                                           nx=nx, ny=ny)
        from .core.overlay import _simulate_batch
        return _simulate_batch(graph_or_gm, batch, nx=nx, ny=ny)
    if mesh is not None:
        from .core.distributed import _simulate_sharded
        return _simulate_sharded(graph_or_gm, mesh, cfg, nx=nx, ny=ny)
    from .core.overlay import _simulate
    return _simulate(graph_or_gm, cfg, nx=nx, ny=ny)
