"""repro — out-of-order dataflow scheduling for FPGA overlays, in JAX.

Package front door. The two names most callers need:

  * :func:`repro.run` — the unified simulate dispatcher (single / batched /
    sharded / batched-sharded engine paths picked from its arguments);
  * :mod:`repro.service` — the batched placement-and-simulation service
    (content-hash result cache, batched query execution, Pareto explorer).

Both are loaded lazily so ``import repro`` stays free of JAX import cost
until an engine is actually used.
"""
from __future__ import annotations

__all__ = ["run", "service"]


def __getattr__(name):
    if name == "run":
        from .api import run
        return run
    if name == "service":
        import importlib
        return importlib.import_module(".service", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
