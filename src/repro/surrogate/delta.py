"""O(degree) incremental surrogate features for single-item moves.

The PR-4 surrogate only ever scored *whole* placements: one
:meth:`FeatureExtractor.features_batch` call per candidate set, O(E) work per
candidate. A placement annealer proposes ~10^5-10^6 single-node moves — far
too many for full re-extraction, but each move ``i: p -> q`` only touches

  * the hop terms of the edges incident to ``i`` (traffic, inject/eject,
    ring loads) — O(degree) via the same padded incidence-table gather the
    annealer's cost delta uses;
  * two entries of every per-PE accumulator (loads / counts / depth
    histogram) — O(1) scatters;
  * the max / sum-of-squares readouts — O(P) reductions over the carried
    per-PE vectors (P = grid size, tiny next to E).

:func:`apply_move` therefore maintains a :class:`GuideState` of carried
integer accumulators and returns the *exact* post-move feature vector: after
any accepted-move sequence the carried features equal a fresh
``features_batch`` bit-for-bit (pinned in ``tests/test_guided.py``). That
exactness is what lets the guided annealer's accept decisions be reproduced
— and CI-gated — anywhere.

Integer-quantized guide
-----------------------
A fitted ridge model predicts ``y_mean + ((f - mu) / sigma) @ beta``; for a
move only the *delta* matters and the affine parts cancel::

    pred(new) - pred(old) = sum_j (beta_j / sigma_j) * (f_new_j - f_old_j)

Feature deltas are exact int64, but ``beta/sigma`` is float64 — and a float
accept rule would make the guided search depend on BLAS/XLA rounding, which
would break the bit-exact CI cycle gates. :func:`build_guide` therefore
quantizes ``gamma = beta/sigma`` to integers (``gamma_q = rint(gamma *
GUIDE_SCALE)``), so the whole two-stage accept — surrogate gate *and*
integer cost threshold — is int64 arithmetic, bit-deterministic across
machines like everything else in :mod:`repro.place.anneal`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .features import (
    DEPTH_BUCKETS,
    FeatureExtractor,
    assemble_features,
    coarsen_extractor,
)
from .model import SurrogateModel

#: fixed-point scale of the quantized guide coefficients: predicted-cycle
#: deltas (and ``guide_margin``) are compared in units of 1/GUIDE_SCALE
#: cycles.
GUIDE_SCALE = 1 << 16


@dataclasses.dataclass(frozen=True)
class Guide:
    """A fitted surrogate, reduced to what move scoring needs.

    ``gamma_q`` are the integer-quantized per-feature slopes; the extractor
    supplies the static tables the deltas are computed from. Build with
    :func:`build_guide`; derive a coarse-level guide for a cluster quotient
    graph with :meth:`coarsen` (same slopes — quotient features are exactly
    the projected fine features, see
    :func:`repro.surrogate.features.coarsen_extractor`).
    """

    extractor: FeatureExtractor
    gamma_q: np.ndarray   # [F] int64, rint(beta / sigma * GUIDE_SCALE)

    def coarsen(self, clusters: np.ndarray) -> "Guide":
        return Guide(extractor=coarsen_extractor(self.extractor, clusters),
                     gamma_q=self.gamma_q)


def build_guide(model: SurrogateModel) -> Guide:
    """Quantize a fitted :class:`SurrogateModel` into an annealer guide."""
    gamma = np.asarray(model.beta, np.float64) / np.asarray(model.sigma,
                                                            np.float64)
    return Guide(extractor=model.extractor,
                 gamma_q=np.rint(gamma * GUIDE_SCALE).astype(np.int64))


def quantize_margin(margin: float) -> int:
    """``guide_margin`` (predicted cycles) -> the int64 gate threshold."""
    if not np.isfinite(margin):
        return int(np.iinfo(np.int64).max) if margin > 0 \
            else int(np.iinfo(np.int64).min)
    return int(np.rint(float(margin) * GUIDE_SCALE))


class GuideArrays(NamedTuple):
    """Static tables of a :class:`Guide` as a jit-friendly pytree.

    Raw ``[E]``/``[N]`` tables drive the O(E) :func:`state_init`; the
    ``*_inc [N, D]`` incidence-layout tables (one row per item, padded to the
    max total degree, zero-weight entries are padding) drive the O(degree)
    :func:`apply_move` gathers, exactly like the annealer's cost tables.
    Built as host int64 numpy (:func:`guide_arrays`); the jit boundary
    converts them under the annealer's scoped x64.
    """

    src: np.ndarray        # [E] int32
    dst: np.ndarray        # [E] int32
    w_edge: np.ndarray     # [E] int64
    c_unit: np.ndarray     # [E] int64
    e_unit: np.ndarray     # [E] int64
    w_node: np.ndarray     # [N] int64
    n_unit: np.ndarray     # [N] int64
    w_bucket: np.ndarray   # [N, DEPTH_BUCKETS] int64
    nbr: np.ndarray        # [N, D] int32 incident-edge other endpoint
    out_inc: np.ndarray    # [N, D] bool: item is the edge source
    w_inc: np.ndarray      # [N, D] int64 edge weight (0 = padding)
    c_inc: np.ndarray      # [N, D] int64 critical-edge multiplicity
    u_inc: np.ndarray      # [N, D] int64 edge multiplicity
    gamma_q: np.ndarray    # [F] int64


class GuideState(NamedTuple):
    """Carried per-placement feature accumulators + the assembled features."""

    t_w: jnp.ndarray        # scalar int64 weighted hop traffic
    t_u: jnp.ndarray        # scalar int64 unweighted hop traffic
    t_c: jnp.ndarray        # scalar int64 critical-chain hop traffic
    loads: jnp.ndarray      # [P] int64 criticality-weighted load
    counts: jnp.ndarray     # [P] int64 item-count load
    inject: jnp.ndarray     # [P] int64 remote packets leaving
    eject: jnp.ndarray      # [P] int64 remote packets landing
    ring_x: jnp.ndarray     # [ny] int64 X-ring hop-weighted traffic
    ring_y: jnp.ndarray     # [nx] int64 Y-ring hop-weighted traffic
    lvl: jnp.ndarray        # [DEPTH_BUCKETS, P] int64 per-level load
    feats: jnp.ndarray      # [F] int64 assembled feature vector


def guide_arrays(guide: Guide) -> GuideArrays:
    """Pack a :class:`Guide` into device tables (host-side, once per search)."""
    # Deferred import: repro.place imports this module's consumers at package
    # init; the incidence builders live with the annealer they were made for.
    from ..place.anneal import (incidence_from_edges, incidence_layout,
                                incidence_payload)

    ex = guide.extractor
    n = ex.num_items
    # One O(E log E) layout sort serves all three incidence tables.
    layout = incidence_layout(ex.src, ex.dst, n)
    nbr, w_inc, out_inc = incidence_from_edges(ex.src, ex.dst, ex.w_edge, n,
                                               layout=layout)
    c_inc = incidence_payload(ex.src, ex.dst, ex.c_unit, n, layout=layout)
    u_inc = incidence_payload(ex.src, ex.dst, ex.e_unit, n, layout=layout)
    # Host numpy int64 throughout: the arrays cross into jax at the jit
    # boundary, inside the annealer's scoped x64 (an eager jnp.asarray here
    # would silently truncate to int32 when x64 is off).
    i64 = lambda a: np.asarray(a, np.int64)
    return GuideArrays(
        src=np.asarray(ex.src), dst=np.asarray(ex.dst),
        w_edge=i64(ex.w_edge), c_unit=i64(ex.c_unit), e_unit=i64(ex.e_unit),
        w_node=i64(ex.w_node), n_unit=i64(ex.n_unit),
        w_bucket=i64(ex.w_bucket),
        nbr=np.asarray(nbr), out_inc=np.asarray(out_inc),
        w_inc=i64(w_inc),
        c_inc=i64(c_inc), u_inc=i64(u_inc),
        gamma_q=i64(guide.gamma_q),
    )


def state_init(ga: GuideArrays, pe, *, nx: int, ny: int) -> GuideState:
    """Full O(E) feature-state computation of one ``[N]`` placement.

    Must run under scoped x64 (the annealer already does); arithmetic
    mirrors :meth:`FeatureExtractor.features_batch` term for term.
    """
    P = nx * ny
    pe = jnp.asarray(pe, jnp.int32)
    ps, pd = pe[ga.src], pe[ga.dst]
    sx, sy = ps // ny, ps % ny
    dx, dy = pd // ny, pd % ny
    hx = jnp.mod(dx - sx, nx).astype(jnp.int64)
    hy = jnp.mod(dy - sy, ny).astype(jnp.int64)
    hops = hx + hy
    remote = (hops > 0).astype(jnp.int64)

    t_w = jnp.sum(ga.w_edge * hops)
    t_u = jnp.sum(ga.e_unit * hops)
    t_c = jnp.sum(ga.c_unit * hops)
    loads = jnp.zeros(P, jnp.int64).at[pe].add(ga.w_node)
    counts = jnp.zeros(P, jnp.int64).at[pe].add(ga.n_unit)
    inject = jnp.zeros(P, jnp.int64).at[ps].add(ga.e_unit * remote)
    eject = jnp.zeros(P, jnp.int64).at[pd].add(ga.e_unit * remote)
    ring_x = jnp.zeros(ny, jnp.int64).at[sy].add(ga.w_edge * hx)
    ring_y = jnp.zeros(nx, jnp.int64).at[dx].add(ga.w_edge * hy)
    lvl = jnp.zeros((DEPTH_BUCKETS, P), jnp.int64).at[:, pe].add(ga.w_bucket.T)
    feats = assemble_features(t_w, t_u, t_c, loads, counts, inject, eject,
                              ring_x, ring_y, lvl)
    return GuideState(t_w=t_w, t_u=t_u, t_c=t_c, loads=loads, counts=counts,
                      inject=inject, eject=eject, ring_x=ring_x,
                      ring_y=ring_y, lvl=lvl, feats=feats)


def apply_move(ga: GuideArrays, st: GuideState, pe, i, q,
               *, nx: int, ny: int) -> tuple[GuideState, jnp.ndarray]:
    """Tentative post-move state of ``i -> q`` plus the quantized score.

    Returns ``(new_state, dscore_q)`` where ``dscore_q = gamma_q @ (f_new -
    f_old)`` — ``GUIDE_SCALE`` times the predicted cycle delta, exact int64.
    The caller commits or discards the state based on its accept rule (the
    annealer selects with ``jnp.where``; a rejected move simply keeps the old
    state). Only ``i``'s incident edges are gathered — O(degree) — plus O(P)
    reductions for the max/sum-of-squares readouts.
    """
    pe = jnp.asarray(pe, jnp.int32)
    p = pe[i]
    nb, out = ga.nbr[i], ga.out_inc[i]
    w, cu, uu = ga.w_inc[i], ga.c_inc[i], ga.u_inc[i]   # 0 on padding entries
    o = pe[nb]
    ox, oy = o // ny, o % ny
    px, py = p // ny, p % ny
    qx, qy = q // ny, q % ny

    # Dimension-ordered hops per incident edge, before/after the move: for
    # out-edges i is the source (hx = dst_x - src_x mod nx), for in-edges the
    # destination. Padding entries carry weight/multiplicity 0 everywhere
    # they are summed or scattered, so they contribute nothing.
    hx_old = jnp.where(out, jnp.mod(ox - px, nx),
                       jnp.mod(px - ox, nx)).astype(jnp.int64)
    hy_old = jnp.where(out, jnp.mod(oy - py, ny),
                       jnp.mod(py - oy, ny)).astype(jnp.int64)
    hx_new = jnp.where(out, jnp.mod(ox - qx, nx),
                       jnp.mod(qx - ox, nx)).astype(jnp.int64)
    hy_new = jnp.where(out, jnp.mod(oy - qy, ny),
                       jnp.mod(qy - oy, ny)).astype(jnp.int64)
    h_old, h_new = hx_old + hy_old, hx_new + hy_new
    dh = h_new - h_old
    r_old = (h_old > 0).astype(jnp.int64)
    r_new = (h_new > 0).astype(jnp.int64)

    src_old = jnp.where(out, p, o)
    src_new = jnp.where(out, q, o)
    dst_old = jnp.where(out, o, p)
    dst_new = jnp.where(out, o, q)

    t_w = st.t_w + jnp.sum(w * dh)
    t_u = st.t_u + jnp.sum(uu * dh)
    t_c = st.t_c + jnp.sum(cu * dh)
    inject = st.inject.at[src_old].add(-uu * r_old).at[src_new].add(uu * r_new)
    eject = st.eject.at[dst_old].add(-uu * r_old).at[dst_new].add(uu * r_new)
    ring_x = st.ring_x.at[src_old % ny].add(-w * hx_old) \
                      .at[src_new % ny].add(w * hx_new)
    ring_y = st.ring_y.at[dst_old // ny].add(-w * hy_old) \
                      .at[dst_new // ny].add(w * hy_new)

    wn, nu = ga.w_node[i], ga.n_unit[i]
    loads = st.loads.at[p].add(-wn).at[q].add(wn)
    counts = st.counts.at[p].add(-nu).at[q].add(nu)
    lvl = st.lvl.at[:, p].add(-ga.w_bucket[i]).at[:, q].add(ga.w_bucket[i])

    feats = assemble_features(t_w, t_u, t_c, loads, counts, inject, eject,
                              ring_x, ring_y, lvl)
    dscore = jnp.sum(ga.gamma_q * (feats - st.feats))
    new = GuideState(t_w=t_w, t_u=t_u, t_c=t_c, loads=loads, counts=counts,
                     inject=inject, eject=eject, ring_x=ring_x,
                     ring_y=ring_y, lvl=lvl, feats=feats)
    return new, dscore
