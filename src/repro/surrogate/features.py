"""Cheap, fully-vmapped placement features for cycle-count prediction.

A feature vector summarizes how a candidate ``[N]`` node -> PE placement
stresses the overlay, using only static graph tables (no simulation):

  * **traffic** — hop-weighted NoC load (weighted / unweighted / critical-
    chain-only sums over the unidirectional-torus hop counts the simulator
    charges);
  * **slot pressure** — per-PE criticality-weighted load: sum of squares and
    max (each PE fires at most one node per cycle, so piled load serializes),
    plus the unweighted slot-count shape (max local memory depth);
  * **port contention** — per-PE counts of remote packets that must leave
    (inject, 1/PE/cycle) and land (eject, 1 port/PE/cycle): sum of squares
    and max of each;
  * **ring load** — traffic per X-ring / Y-ring of the Hoplite torus (a
    packet moves east along its source row, then south along its destination
    column): max and sum-of-squares of each — hot rings deflect;
  * **criticality-depth histogram** — per ASAP-depth-bucket per-PE weighted
    load, reduced to max and sum-of-squares per bucket: the dataflow wavefront
    sweeps depth levels in order, so imbalance *within* a level serializes
    that level no matter how balanced the total is.

Every term is an integer accumulation (scoped x64 — no global flag), so the
feature matrix is bit-reproducible across machines, and the whole batch
extracts as one ``jax.vmap`` on-device.

Multiplicity tables
-------------------
The extractor carries three *unit* tables that default to the trivial values
at the fine (one-row-per-graph-edge) level but let a **quotient graph** of
node clusters compute the exact same features its projected fine placement
would have (:func:`coarsen_extractor`):

  * ``e_unit``  — [E] edges represented by this (aggregated) edge (fine: 1);
  * ``c_unit``  — [E] *critical* edges represented (fine: the 0/1 crit flag);
  * ``n_unit``  — [N] nodes represented by this item (fine: 1);
  * ``w_bucket`` — [N, DEPTH_BUCKETS] criticality weight per ASAP-depth
    bucket (fine: ``w_node`` one-hot at the node's own bucket; its row sums
    always equal ``w_node``).

With unit defaults the arithmetic is identical to the plain per-node
formulas, so fine-level feature matrices are bit-identical to the pre-unit
extractor. With cluster-aggregated units, every feature of a cluster
placement equals — bit for bit — the fine feature of the projected placement
``node_pe = cluster_pe[clusters]`` (intra-cluster edges travel 0 hops, so
dropping them changes nothing). That exactness is what lets the multilevel
placer's *coarse* phase consult the surrogate fitted on fine placements
(:mod:`repro.surrogate.delta`), and it is pinned by tests.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.criticality import asap_levels
from ..core.graph import DataflowGraph
from ..place.cost import edge_tables

#: ASAP-depth buckets in the criticality-depth histogram block.
DEPTH_BUCKETS = 8


def assemble_features(t_w, t_u, t_c, loads, counts, inject, eject,
                      ring_x, ring_y, lvl):
    """[F] int64 feature vector from the raw accumulators.

    THE single definition of the feature order — both the batch extractor
    below and the incremental delta path (:mod:`repro.surrogate.delta`)
    build their vectors through it, so they cannot drift apart.
    """
    return jnp.concatenate([
        jnp.stack([
            t_w, t_u, t_c,
            jnp.sum(loads * loads), loads.max(),
            jnp.sum(counts * counts), counts.max(),
            jnp.sum(inject * inject), inject.max(),
            jnp.sum(eject * eject), eject.max(),
            jnp.maximum(ring_x.max(), ring_y.max()),
            jnp.sum(ring_x * ring_x) + jnp.sum(ring_y * ring_y),
        ]),
        lvl.max(axis=1),
        jnp.sum(lvl * lvl, axis=1),
    ])


@dataclasses.dataclass(frozen=True)
class FeatureExtractor:
    """Static per-graph tables + the vmapped feature function."""

    nx: int
    ny: int
    src: np.ndarray           # [E] int32 edge source item
    dst: np.ndarray           # [E] int32 edge destination item
    w_edge: np.ndarray        # [E] int32 criticality edge weight
    w_node: np.ndarray        # [N] int32 criticality item weight
    c_unit: np.ndarray        # [E] int32 critical fine edges represented
    e_unit: np.ndarray        # [E] int32 fine edges represented (fine: 1)
    n_unit: np.ndarray        # [N] int32 fine nodes represented (fine: 1)
    w_bucket: np.ndarray      # [N, DEPTH_BUCKETS] int32 weight per ASAP bucket

    @property
    def num_pes(self) -> int:
        return self.nx * self.ny

    @property
    def num_items(self) -> int:
        return self.w_node.shape[0]

    @property
    def num_features(self) -> int:
        return 13 + 2 * DEPTH_BUCKETS

    @functools.cached_property
    def _batch_fn(self):
        nx, ny, P = self.nx, self.ny, self.num_pes
        src = jnp.asarray(self.src)
        dst = jnp.asarray(self.dst)
        db = jnp.asarray(self.w_bucket)

        def one(pe, w_edge, c_unit, e_unit, w_node, n_unit):
            pe = jnp.asarray(pe, jnp.int32)
            ps, pd = pe[src], pe[dst]
            sx, sy = ps // ny, ps % ny
            dx, dy = pd // ny, pd % ny
            hx = jnp.mod(dx - sx, nx).astype(jnp.int64)
            hy = jnp.mod(dy - sy, ny).astype(jnp.int64)
            hops = hx + hy
            remote = (hops > 0).astype(jnp.int64)

            t_w = jnp.sum(w_edge * hops)
            t_u = jnp.sum(e_unit * hops)
            t_c = jnp.sum(c_unit * hops)

            loads = jnp.zeros(P, jnp.int64).at[pe].add(w_node)
            counts = jnp.zeros(P, jnp.int64).at[pe].add(n_unit)
            inject = jnp.zeros(P, jnp.int64).at[ps].add(e_unit * remote)
            eject = jnp.zeros(P, jnp.int64).at[pd].add(e_unit * remote)

            # Ring loads: east hops run on the source row (X-ring sy), south
            # hops on the destination column (Y-ring dx) — dimension order.
            ring_x = jnp.zeros(ny, jnp.int64).at[sy].add(w_edge * hx)
            ring_y = jnp.zeros(nx, jnp.int64).at[dx].add(w_edge * hy)

            # [DEPTH_BUCKETS, P] weighted load per (wavefront level, PE).
            lvl = jnp.zeros((DEPTH_BUCKETS, P), jnp.int64).at[:, pe].add(
                db.T.astype(jnp.int64))

            return assemble_features(t_w, t_u, t_c, loads, counts, inject,
                                     eject, ring_x, ring_y, lvl)

        @jax.jit
        def batch(pes):
            args = [jnp.asarray(a, jnp.int64) for a in
                    (self.w_edge, self.c_unit, self.e_unit,
                     self.w_node, self.n_unit)]
            return jax.vmap(lambda p: one(p, *args))(pes)

        return batch

    def features_batch(self, placements) -> np.ndarray:
        """[B, F] float64 feature matrix of a stacked [B, N] candidate batch.

        All accumulations are int64 under scoped x64 and the features are
        exact integers, so the matrix is bit-identical across machines.
        """
        placements = np.asarray(placements, dtype=np.int32)
        if placements.ndim == 1:
            placements = placements[None]
        n = self.w_node.shape[0]
        if placements.shape[-1] != n:
            # Without this, jit's clamping gather would silently score a
            # placement of the WRONG graph instead of erroring.
            raise ValueError(
                f"placements are [B, {placements.shape[-1]}] but this "
                f"extractor was built for a {n}-node graph on a "
                f"{self.nx}x{self.ny} grid")
        if placements.size and (placements.min() < 0
                                or placements.max() >= self.num_pes):
            raise ValueError(
                f"placement references PEs outside the {self.nx}x{self.ny} "
                f"grid")
        with enable_x64():
            out = self._batch_fn(jnp.asarray(placements))
            return np.asarray(out).astype(np.float64)


def features_from_tables(
    nx: int,
    ny: int,
    src: np.ndarray,
    dst: np.ndarray,
    w_edge: np.ndarray,
    w_node: np.ndarray,
    *,
    c_unit: np.ndarray | None = None,
    e_unit: np.ndarray | None = None,
    n_unit: np.ndarray | None = None,
    w_bucket: np.ndarray | None = None,
    depth: np.ndarray | None = None,
) -> FeatureExtractor:
    """Build an extractor directly from flat integer scoring tables.

    Defaults reproduce the fine-level (per-graph-node) semantics: unit
    multiplicities of 1, ``c_unit`` from the top-weight-class rule, and a
    one-hot ``w_bucket`` from ``depth`` (ASAP levels; all-zero when absent).
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    w_edge = np.asarray(w_edge, dtype=np.int32)
    w_node = np.asarray(w_node, dtype=np.int32)
    n = w_node.shape[0]
    if c_unit is None:
        # "critical chain": edges carrying the top integer weight class.
        c_unit = (w_edge >= int(w_edge.max(initial=1))).astype(np.int32)
    if e_unit is None:
        e_unit = np.ones_like(w_edge)
    if n_unit is None:
        n_unit = np.ones_like(w_node)
    if w_bucket is None:
        if depth is None:
            depth = np.zeros(n, dtype=np.int64)
        depth = np.asarray(depth, dtype=np.int64)
        top = max(1, int(depth.max(initial=0)) + 1)
        bucket = (depth * DEPTH_BUCKETS // top).astype(np.int64)
        w_bucket = np.zeros((n, DEPTH_BUCKETS), dtype=np.int32)
        w_bucket[np.arange(n), bucket] = w_node
    w_bucket = np.asarray(w_bucket, dtype=np.int32)
    if w_bucket.shape != (n, DEPTH_BUCKETS):
        raise ValueError(
            f"w_bucket must be [{n}, {DEPTH_BUCKETS}], got {w_bucket.shape}")
    return FeatureExtractor(
        nx=nx, ny=ny, src=src, dst=dst, w_edge=w_edge, w_node=w_node,
        c_unit=np.asarray(c_unit, dtype=np.int32),
        e_unit=np.asarray(e_unit, dtype=np.int32),
        n_unit=np.asarray(n_unit, dtype=np.int32),
        w_bucket=w_bucket,
    )


def build_features(
    g: DataflowGraph,
    nx: int,
    ny: int,
    *,
    metric: str = "height",
    crit_scale: int = 3,
) -> FeatureExtractor:
    """Precompute the static feature tables for ``g`` on an ``nx x ny`` grid."""
    src, dst, w_edge, w_node = edge_tables(g, metric=metric,
                                           crit_scale=crit_scale)
    return features_from_tables(nx, ny, src, dst, w_edge, w_node,
                                depth=asap_levels(g))


def coarsen_extractor(ex: FeatureExtractor,
                      clusters: np.ndarray) -> FeatureExtractor:
    """Quotient-graph extractor whose features are EXACTLY the fine ones.

    Aggregates the fine tables over a ``[N] node -> cluster`` map: parallel
    inter-cluster edges sum their weights and unit multiplicities, cluster
    weights/units/bucket rows are member sums, and intra-cluster edges are
    dropped (their hops are 0 wherever the cluster lands, so every feature
    term they touch is 0 anyway). For any cluster placement ``cpe``::

        coarsen_extractor(ex, clusters).features_batch(cpe)
            == ex.features_batch(cpe[clusters])        # bit-exact

    Quotient edges are ordered by ``(src_cluster * C + dst_cluster)`` —
    identical to :func:`repro.place.coarsen.quotient_tables`, so a guide
    built from this extractor shares the coarse annealer's incidence layout.
    """
    clusters = np.asarray(clusters, dtype=np.int64)
    n = ex.num_items
    if clusters.shape != (n,):
        raise ValueError(f"clusters must be [{n}] item->cluster, "
                         f"got {clusters.shape}")
    c = int(clusters.max(initial=-1)) + 1
    csrc, cdst = clusters[ex.src], clusters[ex.dst]
    cross = csrc != cdst
    pair = csrc[cross] * c + cdst[cross]
    uniq, inv = np.unique(pair, return_inverse=True)

    def agg_edge(v):
        out = np.zeros(len(uniq), dtype=np.int64)
        np.add.at(out, inv, np.asarray(v, np.int64)[cross])
        return out.astype(np.int32)

    def agg_node(v):
        out = np.zeros(c, dtype=np.int64)
        np.add.at(out, clusters, np.asarray(v, np.int64))
        return out.astype(np.int32)

    w_bucket = np.zeros((c, DEPTH_BUCKETS), dtype=np.int64)
    np.add.at(w_bucket, clusters, ex.w_bucket.astype(np.int64))
    return FeatureExtractor(
        nx=ex.nx, ny=ex.ny,
        src=(uniq // c).astype(np.int32), dst=(uniq % c).astype(np.int32),
        w_edge=agg_edge(ex.w_edge),
        w_node=agg_node(ex.w_node),
        c_unit=agg_edge(ex.c_unit),
        e_unit=agg_edge(ex.e_unit),
        n_unit=agg_node(ex.n_unit),
        w_bucket=w_bucket.astype(np.int32),
    )
