"""Cheap, fully-vmapped placement features for cycle-count prediction.

A feature vector summarizes how a candidate ``[N]`` node -> PE placement
stresses the overlay, using only static graph tables (no simulation):

  * **traffic** — hop-weighted NoC load (weighted / unweighted / critical-
    chain-only sums over the unidirectional-torus hop counts the simulator
    charges);
  * **slot pressure** — per-PE criticality-weighted load: sum of squares and
    max (each PE fires at most one node per cycle, so piled load serializes),
    plus the unweighted slot-count shape (max local memory depth);
  * **port contention** — per-PE counts of remote packets that must leave
    (inject, 1/PE/cycle) and land (eject, 1 port/PE/cycle): sum of squares
    and max of each;
  * **ring load** — traffic per X-ring / Y-ring of the Hoplite torus (a
    packet moves east along its source row, then south along its destination
    column): max and sum-of-squares of each — hot rings deflect;
  * **criticality-depth histogram** — per ASAP-depth-bucket per-PE weighted
    load, reduced to max and sum-of-squares per bucket: the dataflow wavefront
    sweeps depth levels in order, so imbalance *within* a level serializes
    that level no matter how balanced the total is.

Every term is an integer accumulation (scoped x64 — no global flag), so the
feature matrix is bit-reproducible across machines, and the whole batch
extracts as one ``jax.vmap`` on-device.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.criticality import asap_levels
from ..core.graph import DataflowGraph
from ..place.cost import edge_tables

#: ASAP-depth buckets in the criticality-depth histogram block.
DEPTH_BUCKETS = 8


@dataclasses.dataclass(frozen=True)
class FeatureExtractor:
    """Static per-graph tables + the vmapped feature function."""

    nx: int
    ny: int
    src: np.ndarray          # [E] int32 edge source node
    dst: np.ndarray          # [E] int32 edge destination node
    w_edge: np.ndarray       # [E] int32 criticality edge weight
    w_node: np.ndarray       # [N] int32 criticality node weight
    crit_edge: np.ndarray    # [E] bool: edge on the (near-)critical chain
    depth_bucket: np.ndarray  # [N] int32 ASAP-depth bucket in [0, DEPTH_BUCKETS)

    @property
    def num_pes(self) -> int:
        return self.nx * self.ny

    @property
    def num_features(self) -> int:
        return 13 + 2 * DEPTH_BUCKETS

    @functools.cached_property
    def _batch_fn(self):
        nx, ny, P = self.nx, self.ny, self.num_pes
        src = jnp.asarray(self.src)
        dst = jnp.asarray(self.dst)
        crit_edge = jnp.asarray(self.crit_edge)
        db = jnp.asarray(self.depth_bucket)

        def one(pe, w_edge, w_node):
            pe = jnp.asarray(pe, jnp.int32)
            ps, pd = pe[src], pe[dst]
            sx, sy = ps // ny, ps % ny
            dx, dy = pd // ny, pd % ny
            hx = jnp.mod(dx - sx, nx).astype(jnp.int64)
            hy = jnp.mod(dy - sy, ny).astype(jnp.int64)
            hops = hx + hy
            remote = (hops > 0).astype(jnp.int64)

            t_w = jnp.sum(w_edge * hops)
            t_u = jnp.sum(hops)
            t_c = jnp.sum(jnp.where(crit_edge, hops, 0))

            loads = jnp.zeros(P, jnp.int64).at[pe].add(w_node)
            counts = jnp.zeros(P, jnp.int64).at[pe].add(1)
            inject = jnp.zeros(P, jnp.int64).at[ps].add(remote)
            eject = jnp.zeros(P, jnp.int64).at[pd].add(remote)

            # Ring loads: east hops run on the source row (X-ring sy), south
            # hops on the destination column (Y-ring dx) — dimension order.
            ring_x = jnp.zeros(ny, jnp.int64).at[sy].add(w_edge * hx)
            ring_y = jnp.zeros(nx, jnp.int64).at[dx].add(w_edge * hy)

            # [DEPTH_BUCKETS, P] weighted load per (wavefront level, PE).
            lvl = jnp.zeros((DEPTH_BUCKETS, P), jnp.int64).at[db, pe].add(w_node)

            return jnp.concatenate([
                jnp.stack([
                    t_w, t_u, t_c,
                    jnp.sum(loads * loads), loads.max(),
                    jnp.sum(counts * counts), counts.max(),
                    jnp.sum(inject * inject), inject.max(),
                    jnp.sum(eject * eject), eject.max(),
                    jnp.maximum(ring_x.max(), ring_y.max()),
                    jnp.sum(ring_x * ring_x) + jnp.sum(ring_y * ring_y),
                ]),
                lvl.max(axis=1),
                jnp.sum(lvl * lvl, axis=1),
            ])

        @jax.jit
        def batch(pes):
            w_edge = jnp.asarray(self.w_edge, jnp.int64)
            w_node = jnp.asarray(self.w_node, jnp.int64)
            return jax.vmap(lambda p: one(p, w_edge, w_node))(pes)

        return batch

    def features_batch(self, placements) -> np.ndarray:
        """[B, F] float64 feature matrix of a stacked [B, N] candidate batch.

        All accumulations are int64 under scoped x64 and the features are
        exact integers, so the matrix is bit-identical across machines.
        """
        placements = np.asarray(placements, dtype=np.int32)
        if placements.ndim == 1:
            placements = placements[None]
        n = self.w_node.shape[0]
        if placements.shape[-1] != n:
            # Without this, jit's clamping gather would silently score a
            # placement of the WRONG graph instead of erroring.
            raise ValueError(
                f"placements are [B, {placements.shape[-1]}] but this "
                f"extractor was built for a {n}-node graph on a "
                f"{self.nx}x{self.ny} grid")
        if placements.size and (placements.min() < 0
                                or placements.max() >= self.num_pes):
            raise ValueError(
                f"placement references PEs outside the {self.nx}x{self.ny} "
                f"grid")
        with enable_x64():
            out = self._batch_fn(jnp.asarray(placements))
            return np.asarray(out).astype(np.float64)


def build_features(
    g: DataflowGraph,
    nx: int,
    ny: int,
    *,
    metric: str = "height",
    crit_scale: int = 3,
) -> FeatureExtractor:
    """Precompute the static feature tables for ``g`` on an ``nx x ny`` grid."""
    src, dst, w_edge, w_node = edge_tables(g, metric=metric,
                                           crit_scale=crit_scale)
    depth = asap_levels(g)
    top = max(1, int(depth.max(initial=0)) + 1)
    bucket = (depth * DEPTH_BUCKETS // top).astype(np.int32)
    return FeatureExtractor(
        nx=nx, ny=ny,
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        w_edge=w_edge.astype(np.int32), w_node=w_node.astype(np.int32),
        # "critical chain": edges carrying the top integer weight class.
        crit_edge=w_edge >= int(w_edge.max(initial=1)),
        depth_bucket=bucket,
    )
