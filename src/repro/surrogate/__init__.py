"""repro.surrogate — batched cycle-count prediction for placement search.

``repro.place`` (PR 3) scores every candidate placement by full cycle-accurate
simulation — seconds to minutes per candidate at paper scale, which makes any
wide search intractable (ROADMAP: "a cheap learned/regression bridge from
integer cost to cycles"). This package is that bridge:

  * :mod:`.features` — cheap, fully-vmapped integer features of a
    ``(DataflowGraph, placement, grid)`` triple: hop-weighted traffic, slot
    pressure, inject/eject port contention, torus ring loads, and a
    criticality-depth histogram of per-wavefront load imbalance;
  * :mod:`.model`    — deterministic closed-form ridge regression (scoped
    x64, no RNG): bit-reproducible coefficients, microsecond predictions;
  * :mod:`.data`     — self-generated training sets: counter-based-key
    placement sampling + one-compile batched simulation.

Top-level API (mirrors the subsystem contract):

  * :func:`fit` — features + closed-form ridge over (placements, cycles);
  * :func:`fit_from_sim` — sample, simulate, fit, in one call;
  * :func:`predict_batch` / :func:`rank` — score / order a stacked candidate
    batch with a fitted model.

``repro.place.evaluate_placements(..., prune="surrogate", keep_top=k)`` uses
:meth:`SurrogateModel.rank` to simulate only the k best-predicted candidates.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import DataflowGraph
from .data import make_training_set, sample_placements  # noqa: F401
from .delta import (  # noqa: F401
    GUIDE_SCALE,
    Guide,
    build_guide,
)
from .features import (  # noqa: F401
    DEPTH_BUCKETS,
    FeatureExtractor,
    build_features,
    coarsen_extractor,
    features_from_tables,
)
from .model import (  # noqa: F401
    SurrogateModel,
    fit_features,
    spearman,
)


def fit(g: DataflowGraph, nx: int, ny: int, placements, cycles, *,
        metric: str = "height", crit_scale: int = 3,
        ridge: float = 1e-3) -> SurrogateModel:
    """Fit a cycle-count surrogate on simulated ``(placements, cycles)``.

    ``placements`` is a stacked ``[n, N]`` int array (or a list of ``[N]``
    vectors); ``cycles`` the matching simulated cycle counts.
    """
    extractor = build_features(g, nx, ny, metric=metric,
                               crit_scale=crit_scale)
    x = extractor.features_batch(np.stack([np.asarray(p) for p in placements]))
    return fit_features(extractor, x, cycles, ridge=ridge)


def fit_from_sim(g: DataflowGraph, nx: int, ny: int, *, cfg=None,
                 n_train: int = 48, seed: int = 0, mesh=None,
                 metric: str = "height", crit_scale: int = 3,
                 ridge: float = 1e-3):
    """Sample ``n_train`` placements, simulate them, fit.

    Returns ``(model, placements, cycles)`` so callers can account for the
    simulations spent on training (the pruning benchmark reports them).
    """
    placements, cycles = make_training_set(
        g, nx, ny, cfg=cfg, n=n_train, seed=seed, mesh=mesh)
    model = fit(g, nx, ny, placements, cycles, metric=metric,
                crit_scale=crit_scale, ridge=ridge)
    return model, placements, cycles


def predict_batch(model: SurrogateModel, placements) -> np.ndarray:
    """[B] float64 predicted cycle counts (module-level convenience)."""
    return model.predict_batch(placements)


def rank(model: SurrogateModel, placements) -> np.ndarray:
    """[B] candidate indices, best predicted first (module-level convenience)."""
    return model.rank(placements)
