"""Self-generated training sets: sampled placements + their simulated cycles.

The surrogate learns from the simulator itself: sample a spread of candidate
placements (static heuristics, pure randoms, load-imbalanced randoms, and
perturbations of good layouts — the distribution a placement search actually
visits), simulate each once through the shape-unified batched path
(:func:`repro.place.simulate_placements`, one compile for the whole set), and
fit the ridge model on (features, cycles).

Sampling uses the counter-based JAX PRNG (`jax.random.fold_in` per
candidate), so a fixed seed yields the same placements on every machine and
backend — the whole fit is bit-reproducible end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import DataflowGraph
from ..core.partition import place_nodes

#: static heuristics mixed into every sample (searchers start near these).
_STATIC = ("round_robin", "blocked", "clustered", "bulk_clustered",
           "critical_chain")


def sample_placements(g: DataflowGraph, nx: int, ny: int, n: int,
                      seed: int = 0, *,
                      include_static: bool = True) -> np.ndarray:
    """[n, N] int32 candidate placements spanning the search distribution.

    The first ``min(n, 5)`` rows are the static heuristics (skipped with
    ``include_static=False`` — held-out sets must not share rows with a
    training set that included them); the rest cycle deterministically
    through pure randoms, imbalanced randoms confined to a shrinking PE
    prefix (probing the pressure axis), and round-robin / clustered layouts
    with a growing fraction of nodes kicked to random PEs (probing the
    traffic axis near good layouts).
    """
    if n < 1:
        raise ValueError(f"need n >= 1 placements, got {n}")
    num_pes = nx * ny
    N = g.num_nodes
    key = jax.random.key(seed)
    out = []
    if include_static:
        for s in _STATIC[:min(n, len(_STATIC))]:
            out.append(place_nodes(g, num_pes, s))

    kinds = ("random", "imbalanced", "perturb_rr", "perturb_cl")
    i = 0
    while len(out) < n:
        k = jax.random.fold_in(key, i)
        kind = kinds[i % len(kinds)]
        if kind == "random":
            pe = jax.random.randint(k, (N,), 0, num_pes, dtype=jnp.int32)
        elif kind == "imbalanced":
            # Confine to a PE prefix of 1/2, 1/4, or 1/8 of the grid.
            frac = 2 ** (1 + (i // len(kinds)) % 3)
            hi = max(1, num_pes // frac)
            pe = jax.random.randint(k, (N,), 0, hi, dtype=jnp.int32)
        else:
            base = place_nodes(
                g, num_pes, "round_robin" if kind == "perturb_rr" else "clustered")
            # Kick 5% / 20% / 50% of nodes to uniform-random PEs.
            permille = (50, 200, 500)[(i // len(kinds)) % 3]
            k1, k2 = jax.random.split(k)
            move = jax.random.randint(k1, (N,), 0, 1000, dtype=jnp.int32) < permille
            rand = jax.random.randint(k2, (N,), 0, num_pes, dtype=jnp.int32)
            pe = jnp.where(move, rand, jnp.asarray(base))
        out.append(np.asarray(pe, dtype=np.int32))
        i += 1
    return np.stack(out[:n]).astype(np.int32)


def make_training_set(g: DataflowGraph, nx: int, ny: int, *, cfg=None,
                      n: int = 64, seed: int = 0,
                      mesh=None) -> tuple[np.ndarray, np.ndarray]:
    """(placements [n, N] int32, cycles [n] int64): sample, then simulate.

    Every candidate must complete within ``cfg.max_cycles`` — a truncated run
    would poison the regression targets, so it raises instead.
    """
    from ..place.api import simulate_placements

    placements = sample_placements(g, nx, ny, n, seed=seed)
    results = simulate_placements(g, nx, ny, list(placements), cfg, mesh=mesh)
    undone = [i for i, r in enumerate(results) if not r.done]
    if undone:
        raise ValueError(
            f"{len(undone)} training placement(s) hit max_cycles "
            f"(first: {undone[0]}); raise cfg.max_cycles")
    cycles = np.asarray([r.cycles for r in results], dtype=np.int64)
    return placements, cycles
