"""Deterministic closed-form ridge regression from features to cycle counts.

The model is intentionally the simplest thing that ranks well: standardize
the integer feature matrix, center the targets, and solve the ridge normal
equations

    (Xs' Xs + ridge * n * I) beta = Xs' (y - mean(y))

once, in float64 under scoped x64 (``jnp.linalg.solve`` — no iterative
optimizer, no learning-rate knobs, no RNG). For fixed inputs the
coefficients are bit-reproducible run to run, which is what lets tests pin
them with ``assert_array_equal`` and lets CI gate rank quality.

Prediction cost is one [B, F] @ [F] matmul — pruning thousands of candidate
placements costs microseconds, versus seconds-to-minutes of cycle-accurate
simulation each (the bridge ROADMAP asked for).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .features import FeatureExtractor


@dataclasses.dataclass(frozen=True)
class SurrogateModel:
    """Fitted ridge coefficients + the feature extractor they apply to."""

    extractor: FeatureExtractor
    mu: np.ndarray        # [F] float64 feature means (training set)
    sigma: np.ndarray     # [F] float64 feature scales (0 -> 1)
    beta: np.ndarray      # [F] float64 ridge coefficients
    y_mean: float         # training-target mean (intercept)
    ridge: float
    n_train: int

    def predict_batch(self, placements) -> np.ndarray:
        """[B] float64 predicted cycle counts of stacked [B, N] placements."""
        x = self.extractor.features_batch(placements)
        return self.y_mean + ((x - self.mu) / self.sigma) @ self.beta

    def predict(self, placement) -> float:
        return float(self.predict_batch(np.asarray(placement)[None])[0])

    def rank(self, placements) -> np.ndarray:
        """[B] candidate indices, best (fewest predicted cycles) first.

        Stable sort: prediction ties keep candidate order, so the ranking is
        as deterministic as the coefficients.
        """
        return np.argsort(self.predict_batch(placements), kind="stable")


def fit_features(
    extractor: FeatureExtractor,
    features: np.ndarray,
    cycles: np.ndarray,
    *,
    ridge: float = 1e-3,
) -> SurrogateModel:
    """Closed-form ridge fit of ``features [n, F] -> cycles [n]``."""
    x = np.asarray(features, dtype=np.float64)
    y = np.asarray(cycles, dtype=np.float64)
    if x.ndim != 2 or y.shape != (x.shape[0],):
        raise ValueError(
            f"need features [n, F] and cycles [n]; got {x.shape} / {y.shape}")
    if x.shape[0] < 2:
        raise ValueError(f"need >= 2 training placements, got {x.shape[0]}")
    mu = x.mean(axis=0)
    sigma = x.std(axis=0)
    sigma = np.where(sigma == 0, 1.0, sigma)
    y_mean = float(y.mean())
    with enable_x64():
        xs = (jnp.asarray(x) - jnp.asarray(mu)) / jnp.asarray(sigma)
        yc = jnp.asarray(y) - y_mean
        gram = xs.T @ xs + ridge * x.shape[0] * jnp.eye(x.shape[1])
        beta = jnp.linalg.solve(gram, xs.T @ yc)
    return SurrogateModel(
        extractor=extractor,
        mu=mu, sigma=sigma, beta=np.asarray(beta, dtype=np.float64),
        y_mean=y_mean, ridge=float(ridge), n_train=int(x.shape[0]),
    )


def spearman(a, b) -> float:
    """Spearman rank correlation with average-rank ties (pure numpy)."""

    def _ranks(v):
        v = np.asarray(v, dtype=np.float64)
        order = np.argsort(v, kind="stable")
        ranks = np.empty(len(v), dtype=np.float64)
        ranks[order] = np.arange(len(v), dtype=np.float64)
        # Average ranks across ties so equal values compare equal.
        uniq, inv, counts = np.unique(v, return_inverse=True,
                                      return_counts=True)
        sums = np.zeros(len(uniq))
        np.add.at(sums, inv, ranks)
        return sums[inv] / counts[inv]

    ra, rb = _ranks(a), _ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = float(np.sqrt((ra * ra).sum() * (rb * rb).sum()))
    return float((ra * rb).sum() / denom) if denom else 0.0
