"""Fast surrogate smoke: ``python -m repro.surrogate [--smoke]``.

Runs the whole subsystem end to end on a small fig1-family workload in well
under a minute and asserts its contracts:

  * fixed-key fit -> bit-identical coefficients across two fits (the
    determinism CI leans on);
  * in-sample rank quality: Spearman >= 0.8 between predictions and
    simulated cycles on the training set;
  * pruning: ``evaluate_placements(prune="surrogate", keep_top=k)`` returns
    exactly k simulated candidates, and the best of them is close to the
    exhaustive best;
  * multilevel placement: identity-coarsened anneal reproduces the plain
    annealer bit-exactly, and a coarse-annealed placement beats round-robin
    on simulated cycles;
  * guided annealing: the incremental delta features match a batch
    recompute bit-exactly, the open-gate (margin = inf) guided kernel
    reproduces the unguided annealer bit-for-bit, and a margin-0 gate
    filters proposals (cost_evals < proposals) deterministically.

CI runs this as a cheap gate next to the tier-1 tests.
"""
from __future__ import annotations

import sys


def main() -> int:
    import numpy as np

    from repro import place, surrogate
    from repro.core import workloads as wl
    from repro.core.overlay import OverlayConfig

    g = wl.arrow_lu_graph(2, 8, 6, seed=3)
    nx = ny = 8
    cfg = OverlayConfig(max_cycles=200_000)

    # 1. Determinism: same key, same data -> bit-identical coefficients.
    m1, placements, cycles = surrogate.fit_from_sim(
        g, nx, ny, cfg=cfg, n_train=24, seed=0)
    m2 = surrogate.fit(g, nx, ny, placements, cycles)
    np.testing.assert_array_equal(m1.beta, m2.beta)
    np.testing.assert_array_equal(m1.mu, m2.mu)

    # 2. In-sample rank quality.
    rho = surrogate.spearman(m1.predict_batch(placements), cycles)
    assert rho >= 0.8, f"in-sample spearman {rho:.3f} < 0.8"

    # 3. Pruned evaluation: k simulated candidates, near-exhaustive best.
    cands = surrogate.sample_placements(g, nx, ny, 16, seed=7)
    names = {f"cand{i}": p for i, p in enumerate(cands)}
    full = place.evaluate_placements(g, nx, ny, names, cfgs=cfg)
    pruned = place.evaluate_placements(
        g, nx, ny, names, cfgs=cfg, prune="surrogate", keep_top=4,
        surrogate=m1)
    assert len(pruned) == 4 and set(pruned) <= set(full)
    best_full = min(r.cycles for r in full.values())
    best_pruned = min(r.cycles for r in pruned.values())
    assert best_pruned <= 1.10 * best_full, (best_pruned, best_full)

    # 4. Multilevel: identity clusters == plain annealer, bit-exactly;
    #    coarse-annealed beats round-robin on simulated cycles.
    acfg = place.AnnealConfig(replicas=6, rounds=12, steps=256, seed=0)
    plain = place.anneal_placement(g, nx, ny, acfg)
    ident = place.multilevel_anneal(
        g, nx, ny, acfg, clusters=np.arange(g.num_nodes), refine=None)
    np.testing.assert_array_equal(ident.node_pe, plain.node_pe)
    ml = place.multilevel_anneal(
        g, nx, ny, place.AnnealConfig(replicas=8, rounds=16, steps=384, seed=0),
        ratio=8,
        refine=place.AnnealConfig(replicas=6, rounds=12, steps=512, seed=0))
    res = place.evaluate_placements(g, nx, ny, {
        "round_robin": "round_robin", "multilevel": ml.node_pe}, cfgs=cfg)
    rr, mlr = res["round_robin"], res["multilevel"]
    assert rr.done and mlr.done
    assert mlr.cycles < rr.cycles, (mlr.cycles, rr.cycles)

    # 5. Guided annealing: delta features == batch recompute bit-exactly
    #    after a random move sequence; open gate == unguided bit-exactly;
    #    a margin-0 gate actually filters, deterministically.
    from jax.experimental import enable_x64

    from repro.surrogate import delta as sd

    guide = sd.build_guide(m1)
    ga = sd.guide_arrays(guide)
    rng = np.random.default_rng(11)
    pe = rng.integers(0, nx * ny, size=g.num_nodes).astype(np.int32)
    with enable_x64():
        st = sd.state_init(ga, pe, nx=nx, ny=ny)
        for _ in range(64):
            i = int(rng.integers(0, g.num_nodes))
            q = int(rng.integers(0, nx * ny))
            st, _ = sd.apply_move(ga, st, pe, i, np.int32(q), nx=nx, ny=ny)
            pe[i] = q
        np.testing.assert_array_equal(
            np.asarray(st.feats),
            m1.extractor.features_batch(pe)[0].astype(np.int64))
    open_gate = place.anneal_placement(g, nx, ny, acfg, guide=m1,
                                       guide_margin=float("inf"))
    np.testing.assert_array_equal(open_gate.node_pe, plain.node_pe)
    assert open_gate.cost_evals == open_gate.proposals
    g1 = place.anneal_placement(g, nx, ny, acfg, guide=m1, guide_margin=0.0)
    g2 = place.anneal_placement(g, nx, ny, acfg, guide=m1, guide_margin=0.0)
    np.testing.assert_array_equal(g1.node_pe, g2.node_pe)
    assert 0 < g1.cost_evals < g1.proposals
    assert g1.cost <= g1.init_cost

    print(f"surrogate smoke OK: spearman={rho:.3f}, "
          f"pruned best {best_pruned} vs exhaustive {best_full} "
          f"({len(pruned)}/{len(full)} sims), "
          f"multilevel {mlr.cycles} < round_robin {rr.cycles} cycles "
          f"({ml.num_clusters} clusters for {g.num_nodes} nodes), "
          f"guided gate pass-rate {g1.eval_ratio:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
