"""Production training driver.

Wires the full substrate: config registry -> sharded train state -> WSD
AdamW -> deterministic host-sharded data -> jit'd train step (remat + grad
accumulation) -> checkpoint manager with AUTO-RESUME (restart the process
and it continues from the latest checkpoint and the exact data position).

Single-host usage (this container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 200 --batch 16 --seq 64 --ckpt /tmp/run1

On a real cluster each process runs the same command after
``jax.distributed.initialize()`` (hook provided via --distributed); the mesh
comes from launch.mesh and data sharding from process_index.

Fault handling: --sim-fail N raises after N steps (restart resumes); a
SIGTERM checkpoint hook flushes the latest state before preemption.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticCopyTask, SyntheticZipfLM
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim import AdamW, wsd_schedule
from repro.train.steps import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--data", choices=["copy", "zipf"], default="copy")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["local", "single", "multi"], default="local")
    ap.add_argument("--sim-fail", type=int, default=0,
                    help="simulate a crash after N steps (restart resumes)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "local":
        mesh = make_local_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    opt = AdamW(lr=wsd_schedule(args.lr, args.warmup, max(args.steps - args.warmup - args.steps // 5, 1),
                                max(args.steps // 5, 1)), weight_decay=0.01)
    ds_cls = SyntheticCopyTask if args.data == "copy" else SyntheticZipfLM
    ds = ds_cls(cfg.vocab_size, batch=args.batch, seq=args.seq, seed=0,
                num_hosts=jax.process_count(), host_id=jax.process_index())

    state = init_train_state(jax.random.key(0), cfg, opt)
    sspecs = shd.state_specs(cfg, state, mesh)
    state = jax.device_put(state, shd.to_shardings(mesh, sspecs))

    start = 0
    cm = None
    if args.ckpt:
        cm = CheckpointManager(args.ckpt, keep_n=3, async_save=True)
        if cm.latest_step() is not None:
            state = cm.restore_latest(state)
            state = jax.device_put(state, shd.to_shardings(mesh, sspecs))
            start = cm.latest_step()
            print(f"[resume] restored step {start} from {args.ckpt}")

    step_fn = jax.jit(
        make_train_step(cfg, opt, grad_accum=args.grad_accum),
        donate_argnums=0)

    stop = {"flag": False}
    def _sigterm(sig, frame):
        stop["flag"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    pre = Prefetcher(ds, start_step=start)
    t0 = time.time()
    tokens_per_step = args.batch * args.seq
    try:
        with mesh:
            for i in range(start, args.steps):
                step_idx, batch = pre.next()
                assert step_idx == i
                state, m = step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})
                if args.sim_fail and i + 1 == args.sim_fail:
                    if cm:
                        cm.save(i + 1, state)
                        cm.wait()
                    raise RuntimeError(f"[sim-fail] injected failure at step {i + 1}")
                if (i + 1) % args.log_every == 0:
                    dt = time.time() - t0
                    tps = tokens_per_step * args.log_every / max(dt, 1e-9)
                    print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                          f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
                          f"tok/s {tps:,.0f}", flush=True)
                    t0 = time.time()
                if cm and ((i + 1) % args.ckpt_every == 0 or stop["flag"]):
                    cm.save(i + 1, state)
                if stop["flag"]:
                    print("[sigterm] checkpointed and exiting")
                    break
    finally:
        pre.close()
        if cm:
            cm.wait()
    print("done at step", int(state["step"]))
    return state


if __name__ == "__main__":
    main()
