import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, SPMD-
partitions, and compiles on the production meshes, and extract the roofline
terms from the compiled artifact.

The two lines above MUST run before any jax import: jax locks the device
count at first backend init. Only this entry point forces 512 host devices;
tests and benchmarks see the real device list.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single multi --out experiments/dryrun

One JSON record per cell is appended under <out>/; existing records are
skipped, so the sweep is resumable.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.distributed.hlo import collective_bytes  # noqa: E402
from repro.distributed.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.common import ModelConfig  # noqa: E402
from repro.optim import AdamW, wsd_schedule  # noqa: E402
from repro.train import steps as tsteps  # noqa: E402

# v5e-class chip constants for the roofline report (EXPERIMENTS.md §Roofline).
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per-chip effective, 1 link)
HBM_PER_CHIP = 16 * 1024**3


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def abstract_batch(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    s = SHAPES[shape_name]
    b, t = s.global_batch, s.seq_len
    if s.kind == "train":
        if cfg.encdec is not None:
            dec = max(64, t // cfg.encdec.dec_ratio)
            return {"frames": sds((b, t, cfg.d_model), cfg.dtype),
                    "tokens": sds((b, dec), "int32"),
                    "labels": sds((b, dec), "int32")}
        if cfg.family == "vlm":
            return {"embeds": sds((b, t, cfg.d_model), cfg.dtype),
                    "labels": sds((b, t), "int32")}
        return {"tokens": sds((b, t), "int32"), "labels": sds((b, t), "int32")}
    if s.kind == "prefill":
        if cfg.encdec is not None:
            dec = max(64, t // cfg.encdec.dec_ratio)
            return {"frames": sds((b, t, cfg.d_model), cfg.dtype),
                    "tokens": sds((b, dec), "int32")}
        if cfg.family == "vlm":
            return {"embeds": sds((b, t, cfg.d_model), cfg.dtype)}
        return {"tokens": sds((b, t), "int32")}
    # decode
    return {"tokens": sds((b,), "int32")}


def abstract_cache(cfg: ModelConfig, shape_name: str):
    s = SHAPES[shape_name]
    b, t = s.global_batch, s.seq_len
    if cfg.encdec is not None:
        dec = max(64, t // cfg.encdec.dec_ratio)
        return jax.eval_shape(lambda: lm.encdec_init_cache(cfg, b, dec, t))
    return jax.eval_shape(lambda: lm.init_cache(cfg, b, t))


def build_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (jitted_fn, example_args) for lower()."""
    s = SHAPES[shape_name]
    batch_abs = abstract_batch(cfg, shape_name)
    bspecs = shd.batch_specs(cfg, batch_abs, mesh)
    if s.kind == "train":
        opt = AdamW(lr=wsd_schedule(3e-4, 1000, 100_000, 10_000))
        state_abs = jax.eval_shape(
            lambda k: tsteps.init_train_state(k, cfg, opt), jax.random.key(0))
        sspecs = shd.state_specs(cfg, state_abs, mesh)
        fn = tsteps.make_train_step(cfg, opt, grad_accum=cfg.grad_accum)
        jfn = jax.jit(
            fn,
            in_shardings=(shd.to_shardings(mesh, sspecs), shd.to_shardings(mesh, bspecs)),
            out_shardings=(shd.to_shardings(mesh, sspecs), None),
            donate_argnums=0,
        )
        return jfn, (state_abs, batch_abs)

    params_abs = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.key(0))
    pspecs = shd.param_specs(cfg, params_abs, mesh)
    cache_abs = abstract_cache(cfg, shape_name)
    cspecs = shd.cache_specs(cfg, cache_abs, mesh, seq_shard=(s.global_batch == 1))

    if s.kind == "prefill":
        fn = tsteps.make_prefill_step(cfg)
        jfn = jax.jit(
            fn,
            in_shardings=(shd.to_shardings(mesh, pspecs),
                          shd.to_shardings(mesh, bspecs),
                          shd.to_shardings(mesh, cspecs)),
            out_shardings=(None, shd.to_shardings(mesh, cspecs)),
            donate_argnums=2,
        )
        return jfn, (params_abs, abstract_batch(cfg, shape_name), cache_abs)

    # decode: serve_step(params, tokens, cache, cache_len)
    dstep = tsteps.make_decode_step(cfg)

    def serve_step(params, tokens, cache, cache_len):
        nxt, logits, cache = dstep(params, tokens, cache, cache_len)
        return nxt, cache

    tok_abs = abstract_batch(cfg, shape_name)["tokens"]
    dp = shd.data_axes(mesh)
    tok_spec = jax.sharding.PartitionSpec(dp) if s.global_batch > 1 else jax.sharding.PartitionSpec()
    jfn = jax.jit(
        serve_step,
        in_shardings=(shd.to_shardings(mesh, pspecs),
                      jax.sharding.NamedSharding(mesh, tok_spec),
                      shd.to_shardings(mesh, cspecs),
                      jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())),
        out_shardings=(None, shd.to_shardings(mesh, cspecs)),
        donate_argnums=2,
    )
    return jfn, (params_abs, tok_abs, cache_abs, sds((), "int32"))


def run_cell(arch: str, shape_name: str, mesh_name: str, *, keep_hlo_dir=None,
             cfg_override: ModelConfig | None = None, want_profile: bool = False):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="SKIP", reason=reason, wall_s=0.0)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    try:
        jfn, args = build_cell(cfg, shape_name, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ca = compiled.cost_analysis() or {}
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and np.isfinite(float(v))}
        try:
            ma = compiled.memory_analysis()
            mem = {a: int(getattr(ma, a)) for a in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes") if hasattr(ma, a)}
        except Exception as e:  # CPU backend may not implement this
            mem = {"error": str(e)[:200]}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)          # raw, trip-count-blind (reference)
        walk = hlo_analyze(hlo)               # trip-count-aware static analysis
        if want_profile:
            from repro.distributed.hlo_cost import Module  # noqa: PLC0415
            prof = Module(hlo).profile()
            rec["profile"] = dict(sorted(
                prof.items(), key=lambda kv: -kv[1]["bytes"])[:25])
        if keep_hlo_dir:
            import gzip  # noqa: PLC0415
            os.makedirs(keep_hlo_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    keep_hlo_dir, f"{arch}__{shape_name}__{mesh_name}.hlo.gz"),
                    "wt") as f:
                f.write(hlo)
        flops = walk["flops"]
        bytes_acc = walk["bytes"]
        kind = SHAPES[shape_name].kind
        mf = 6 if kind == "train" else 2      # fwd+bwd vs fwd-only per token
        model_flops = mf * lm.active_param_count(cfg) * _tokens(cfg, shape_name)
        rec.update(
            status="OK", chips=chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            cost=cost, memory=mem, collectives=coll, hlo_walk=walk,
            roofline={
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": bytes_acc / HBM_BW,
                "collective_s": walk["collective_traffic"] / ICI_BW,
                "model_flops_total": model_flops,
                "useful_flops_frac": (model_flops / chips) / flops if flops else None,
            },
        )
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def _tokens(cfg, shape_name):
    s = SHAPES[shape_name]
    if s.kind == "train":
        if cfg.encdec is not None:
            return s.global_batch * (s.seq_len + max(64, s.seq_len // cfg.encdec.dec_ratio))
        return s.global_batch * s.seq_len
    if s.kind == "prefill":
        return s.global_batch * s.seq_len
    return s.global_batch  # decode: one token per sequence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single"], choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == ["all"] else args.arch
    shapes = list(SHAPES) if args.shape == ["all"] else args.shape
    os.makedirs(args.out, exist_ok=True)

    for mesh_name in args.mesh:
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"skip (exists): {path}")
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name} ===", flush=True)
                rec = run_cell(arch, shape_name, mesh_name,
                               keep_hlo_dir=os.path.join(args.out, "hlo") if args.keep_hlo else None)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f" compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                             f"coll={r['collective_s']:.4f}s")
                elif status == "FAIL":
                    extra = " " + rec["error"][:160]
                print(f"--- {status} ({rec['wall_s']}s){extra}", flush=True)


if __name__ == "__main__":
    main()
