"""Batched serving driver: prefill a prompt batch, decode N tokens, report
throughput. Works with every registry arch (enc-dec and VLM included).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 8 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.train.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = lm.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    b, p, gen = args.batch, args.prompt_len, args.gen

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    if cfg.encdec is not None:
        batch = {
            "frames": jnp.asarray(rng.standard_normal((b, p * 2, cfg.d_model)), cfg.jdtype),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32),
        }
        cache = lm.encdec_init_cache(cfg, b, max_dec_len=p + gen, enc_len=p * 2)
    elif cfg.family == "vlm":
        batch = {"embeds": jnp.asarray(rng.standard_normal((b, p, cfg.d_model)), cfg.jdtype)}
        cache = lm.init_cache(cfg, b, max_len=p + gen)
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)}
        cache = lm.init_cache(cfg, b, max_len=p + gen)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [cur]
    t0 = time.time()
    for i in range(gen - 1):
        cur, _, cache = decode(params, cur, cache, jnp.int32(p + i))
        outs.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.time() - t0
    seq = np.asarray(jnp.stack(outs, 1))

    print(f"arch={cfg.name} batch={b} prompt={p} gen={gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms  ({b*p/t_prefill:,.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms   ({b*(gen-1)/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample tokens:", seq[0, :12].tolist())
    return seq


if __name__ == "__main__":
    main()
