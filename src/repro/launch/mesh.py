"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single-pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod: 2 x 16 x 16 = 512 chips ("pod", "data", "model"); the "pod" axis
is folded into the data-parallel group by the sharding rules (gradient
all-reduce crosses pods over DCI; everything else stays intra-pod).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 takes axis_types; 0.4.x has neither the kwarg nor AxisType
    # (Auto is the 0.4.x behavior, so omitting it is equivalent).
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/smokes)."""
    return _make_mesh((data, model), ("data", "model"))
