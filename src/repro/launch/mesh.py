"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single-pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod: 2 x 16 x 16 = 512 chips ("pod", "data", "model"); the "pod" axis
is folded into the data-parallel group by the sharding rules (gradient
all-reduce crosses pods over DCI; everything else stays intra-pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/smokes)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
