"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor quantized gradient all-reduce: each worker quantizes
(grad + error_residual) to int8 with a shared per-tensor scale, all-reduces
the int8 payload in int32 (sum of <= 4096 workers cannot overflow), and
dequantizes. The quantization error is carried to the next step (error
feedback), which is what keeps convergence intact (1-bit Adam / EF-SGD
lineage). Cuts gradient all-reduce traffic 4x vs f32 / 2x vs bf16.

Usable two ways:
  * ``compress_roundtrip`` — pure single-process form (tests, unit math);
  * ``make_compressed_psum(axis)`` — drop into a shard_map'd train step to
    replace the mean-gradient psum across the data axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _scale(x):
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0


def quantize(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_roundtrip(grads, err):
    """Quantize (g + err) -> int8 -> dequantize; returns (g_hat, new_err).

    Apply per-leaf. The caller sums g_hat across workers (all-reduce).
    """
    def leaf(g, e):
        x = g.astype(jnp.float32) + e
        s = _scale(x)
        q = quantize(x, s)
        g_hat = dequantize(q, s)
        return g_hat, x - g_hat

    flat = jax.tree.map(leaf, grads, err)
    g_hat = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_psum(axis_name: str):
    """Returns psum_c(grads, err) -> (mean_grads, new_err) for use INSIDE a
    shard_map over ``axis_name``. int8 payload is all-reduced as int32."""

    def psum_c(grads, err):
        n = jax.lax.psum(1, axis_name)

        def leaf(g, e):
            x = g.astype(jnp.float32) + e
            # shared scale: max over workers so the int8 grids agree
            s = jax.lax.pmax(_scale(x), axis_name)
            q = quantize(x, s)
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            g_hat_local = dequantize(q, s)
            mean = total.astype(jnp.float32) * s / n
            return mean, x - g_hat_local

        flat = jax.tree.map(leaf, grads, err)
        mean = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
        return mean, new_err

    return psum_c
