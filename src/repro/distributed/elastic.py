"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints are host arrays (manifest-based), so elasticity reduces to
recomputing the PartitionSpecs for the NEW mesh and ``device_put``-ing the
restored state. Data-parallel rescale keeps per-step semantics by holding
the GLOBAL batch fixed: the pipeline reslices the same deterministic stream
over the new host count (pipeline is a pure function of (seed, step, host)).

Straggler/failure handling at 1000-node scale (documented policy, exercised
by tests at container scale):
  * failure -> the job restarts on the surviving mesh via ``remesh`` +
    checkpoint auto-resume (launch.train does this end-to-end);
  * stragglers -> deterministic data sharding means any host can recompute
    any shard; slow hosts are replaced by restarting with the same host_id;
  * the overlay's deflection-routed NoC (core.noc) is itself the paper's
    straggler-mitigation story at the network level: contended packets
    deflect rather than block.
"""
from __future__ import annotations

import jax

from repro.distributed import sharding as shd


def remesh(cfg, state, new_mesh):
    """Re-shard a (host or device) state pytree onto ``new_mesh``."""
    specs = shd.state_specs(cfg, state, new_mesh)
    return jax.device_put(state, shd.to_shardings(new_mesh, specs))


def rescale_batch(global_batch: int, old_hosts: int, new_hosts: int) -> int:
    """Per-host batch after an elastic resize (global batch invariant)."""
    if global_batch % new_hosts:
        raise ValueError(f"global batch {global_batch} not divisible by {new_hosts} hosts")
    return global_batch // new_hosts
