"""Sharding rules: parameter/optimizer/activation/cache PartitionSpecs.

Scheme (DESIGN.md §6): 2-D TP x DP on mesh axes ("data", "model") — plus a
leading "pod" axis folded into the data-parallel group on multi-pod meshes.

  * column-parallel weights  [d_in, d_out]   -> (fsdp, "model")
  * row-parallel weights     [d_out, d_in']  -> ("model", fsdp)
  * embeddings [V, d] vocab-parallel          -> ("model", fsdp)
    (tied head embed.T => logits vocab-sharded over "model"; the chunked-xent
    logsumexp reduction becomes the TP all-reduce)
  * MoE experts [E, d, f] / [E, f, d]         -> E over "model" (EP),
    d over fsdp — EP rides the TP combine all-reduce (see models/moe.py)
  * small tensors (norms, biases, routers, conv, SSM scalars) replicate
  * optimizer state mirrors params (ZeRO via fsdp axis)

``fsdp`` is the "data" axis when cfg.fsdp else None (replicated).
KV/SSM caches: batch over data for batched decode; **sequence over data** for
long_500k (batch=1) — decode sequence parallelism; kv-heads over "model".
Layer-stacked parameters get a leading None for the stack dim.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fix_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axis size doesn't divide (explicit
    in_shardings require exact divisibility; replication is the safe
    fallback and is recorded in the dry-run report via the spec itself)."""
    dims = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    fixed = []
    for d, ax in zip(shape, dims):
        n = _axis_size(mesh, ax)
        fixed.append(ax if (n > 1 and d % n == 0) or n == 1 else None)
    return P(*fixed)


def _rule(path: str, ndim: int, cfg: ModelConfig):
    """Trailing-dims PartitionSpec for a parameter path."""
    f = "data" if cfg.fsdp else None
    # --- MoE expert tensors (3D, expert-major) ---
    if path.endswith("ffn/w_gate") or path.endswith("ffn/w_up"):
        return ("model", f, None)
    if path.endswith("ffn/w_down"):
        return ("model", None, f)
    if "router" in path:
        return (None, None)
    # --- embeddings / head ---
    if path.endswith("embed"):
        return ("model", f)
    if path.endswith("lm_head"):
        return (f, "model")
    # --- MLA ---
    if "w_dkv" in path:
        return (f, None)
    if "w_uk" in path or "w_uv" in path:
        return (None, "model")
    # --- column-parallel ---
    for k in ("wq/w", "wk/w", "wv/w", "w_gate/w", "w_up/w", "in_proj/w"):
        if path.endswith(k):
            return (f, "model")
    # --- row-parallel ---
    for k in ("wo/w", "w_down/w", "out_proj/w"):
        if path.endswith(k):
            return ("model", f)
    # --- biases on column-parallel outputs ---
    for k in ("wq/b", "wk/b", "wv/b", "w_up/b"):
        if path.endswith(k):
            return ("model",)
    # everything else (norms, conv, A_log, D, dt_bias, wo/b, w_down/b): replicate
    return tuple(None for _ in range(ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_tree, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching ``params_tree`` (arrays or
    ShapeDtypeStructs)."""

    def spec(path, leaf):
        ps = _path_str(path)
        rule = _rule(ps, leaf.ndim, cfg)
        rule = tuple(rule)
        if len(rule) < leaf.ndim:  # stacked layer dims -> leading None
            rule = (None,) * (leaf.ndim - len(rule)) + rule
        elif len(rule) > leaf.ndim:
            rule = rule[-leaf.ndim:]
        return fix_divisibility(P(*rule), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def state_specs(cfg: ModelConfig, state_tree, mesh: Mesh) -> Any:
    """TrainState {params, opt{m,v,master,count}, step} -> specs. Optimizer
    moments/master mirror the param specs."""
    pspecs = param_specs(cfg, state_tree["params"], mesh)
    out = {"params": pspecs, "step": P()}
    opt = {}
    for k in state_tree["opt"]:
        if k == "count":
            opt[k] = P()
        else:
            opt[k] = param_specs(cfg, state_tree["opt"][k], mesh)
    out["opt"] = opt
    return out


def batch_specs(cfg: ModelConfig, batch_tree, mesh: Mesh) -> Any:
    dp = data_axes(mesh)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        return fix_divisibility(
            P(dp, *([None] * (leaf.ndim - 1))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_specs(cfg: ModelConfig, cache_tree, mesh: Mesh, *, seq_shard: bool) -> Any:
    """KV/SSM/latent cache specs.

    seq_shard=False (batched decode): batch dim over data, kv-heads over model.
    seq_shard=True (long_500k, batch=1): sequence dim over data.
    Cache leaves (after layer stacking): attn k/v [L, b, S, hkv, hd];
    mla ckv [L, b, S, kvr], kr [L, b, S, dr]; ssm state [L, b, h, p, n],
    conv [L, b, k-1, c].
    """
    dp = data_axes(mesh)

    def spec(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        if "state" in ps:  # [L, b, h, p, n]
            s = P(None, None, "model", None, None) if seq_shard else P(None, dp, "model", None, None)
        elif "conv" in ps:  # [L, b, k-1, c]
            s = P(None, None, None, "model") if seq_shard else P(None, dp, None, "model")
        elif ps.endswith("k") or ps.endswith("v"):  # [L, b, S, hkv, hd]
            if seq_shard:
                s = P(None, None, dp, "model", None)
            else:
                s = P(None, dp, None, "model", None)
            # kv-head dim often < model size (GQA/MQA): fall back to head_dim
            if leaf.shape[3] % _axis_size(mesh, "model") != 0 and leaf.shape[4] % _axis_size(mesh, "model") == 0:
                s = P(s[0], s[1], s[2], None, "model")
        elif "ckv" in ps or "kr" in ps:  # [L, b, S, r]
            s = P(None, None, dp, None) if seq_shard else P(None, dp, None, None)
        else:
            s = P(*([None] * nd))
        return fix_divisibility(s, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))
