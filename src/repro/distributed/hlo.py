"""Optimized-HLO analysis: collective traffic extraction for the roofline.

``cost_analysis()`` reports FLOPs and memory bytes but not collective bytes,
so we parse the compiled module text. XLA prints operands as bare ``%names``;
the *result* type carries the shape, and ``replica_groups=[G,S]<=[N]`` (or an
explicit group list) carries the group size S. Per-device ICI traffic uses
the ring-algorithm model:

    all-reduce          2 * B * (S-1)/S      (reduce-scatter + all-gather)
    all-gather          B * (S-1)/S          (B = full result bytes)
    reduce-scatter      B * (S-1)            (B = shard result bytes)
    all-to-all          B * (S-1)/S
    collective-permute  B

Async ``-start``/``-done`` pairs are counted once at the start op.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_OP_RE = re.compile(
    r"=\s*(?P<result>.*?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<async>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def _traffic(kind: str, result_bytes: int, s: int) -> float:
    if s <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (s - 1) / s
    if kind == "all-gather":
        return result_bytes * (s - 1) / s
    if kind == "reduce-scatter":
        return result_bytes * (s - 1)
    if kind == "all-to-all":
        return result_bytes * (s - 1) / s
    return float(result_bytes)  # collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective stats from optimized HLO.

    Returns {kind: {bytes, traffic_bytes, count}, total_bytes, total_traffic,
    total_count}; ``bytes`` = raw result bytes, ``traffic_bytes`` = ring-model
    ICI bytes per device (use this for the roofline collective term).
    """
    out: dict = defaultdict(lambda: {"bytes": 0, "traffic_bytes": 0.0, "count": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("async") == "-done":
            continue
        kind = m.group("kind")
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group("result")))
        s = _group_size(line)
        out[kind]["bytes"] += nbytes
        out[kind]["traffic_bytes"] += _traffic(kind, nbytes, s)
        out[kind]["count"] += 1
    result = {k: dict(v) for k, v in out.items()}
    result["total_bytes"] = int(sum(v["bytes"] for v in out.values()))
    result["total_traffic"] = float(sum(v["traffic_bytes"] for v in out.values()))
    result["total_count"] = int(sum(v["count"] for v in out.values()))
    return result
