"""Trip-count-aware static cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, ignoring the trip
count — useless for scan-over-layers models (it under-counts an 80-layer
model by 80x). This walker parses the optimized module, recursively costs
each computation, and multiplies while bodies by their
``backend_config known_trip_count`` (scan always has one), giving:

  * flops            — dots (2*M*N*K), elementwise, reductions
  * bytes            — HBM traffic model: operand+result bytes of every
                       non-fused top-level op (fusion internals are free)
  * collectives      — ring-model ICI traffic per kind (see hlo.collective_bytes)

All numbers are per-device (the module is the per-partition SPMD program).
Unknown trip counts fall back to 1 and are reported in ``unknown_trips``.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

from .hlo import _DTYPE_BYTES, _traffic

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<opcode>[a-z][\w\-]*)\((?P<rest>.*)$"
)
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = {
    "while": ("condition", "body"),
    "fusion": ("calls",),
    "call": ("to_apply",),
    "conditional": (),  # handled specially (branch_computations)
}
_ATTR_COMP = re.compile(r"\b(condition|body|calls|to_apply)=%?([\w\.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "logistic", "sine", "cosine", "floor", "ceil", "round-nearest-afz",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "select", "clamp", "compare", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "convert", "popcnt", "count-leading-zeros",
}
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _nelem(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _result_elems(type_str) -> int:
    return sum(_nelem(s) for _, s in _SHAPE_RE.findall(type_str))


class Module:
    def __init__(self, text: str):
        self.computations: dict[str, list[dict]] = {}
        self.shapes: dict[str, str] = {}  # op name -> result type string
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR.match(line.strip())
            if hdr and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            op = {
                "name": m.group("name"),
                "type": m.group("type"),
                "opcode": m.group("opcode"),
                "line": line,
            }
            self.shapes[op["name"]] = op["type"]
            # operand names: inside the parens up to depth-0 close
            rest = m.group("rest")
            depth, end = 0, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    if depth == 0:
                        end = i
                        break
                    depth -= 1
            op["operands"] = re.findall(r"%([\w\.\-]+)", rest[:end])
            op["attrs"] = rest[end:]
            self.computations[cur].append(op)
        self.entry = next(
            (c for c in self.computations if c.startswith("main")),
            list(self.computations)[-1] if self.computations else None)
        # find ENTRY properly
        for ln in text.splitlines():
            if ln.startswith("ENTRY"):
                m = _COMP_HDR.match(ln.strip())
                if m:
                    self.entry = m.group(1)
        self._memo: dict[tuple, dict] = {}
        self.unknown_trips: list[str] = []

    # ------------------------------------------------------------------
    def _name_bytes(self, name: str) -> int:
        t = self.shapes.get(name)
        if not t:
            return 0
        return sum(_DTYPE_BYTES.get(d, 4) * _nelem(s)
                   for d, s in _SHAPE_RE.findall(t))

    def _operand_bytes(self, op) -> int:
        return sum(self._name_bytes(o) for o in op["operands"])

    def _result_bytes(self, op) -> int:
        return sum(_DTYPE_BYTES.get(d, 4) * _nelem(s)
                   for d, s in _SHAPE_RE.findall(op["type"]))

    def _traffic_bytes(self, op) -> int:
        """Physical HBM traffic model for one top-level op.

        Slicing ops read only the slice, not the buffer: counting the full
        operand would charge a scan body the whole stacked parameter array
        every iteration (the XLA cost-analysis convention, wrong by a factor
        of num_layers here).
        """
        oc = op["opcode"]
        if oc in ("dynamic-slice", "gather"):
            return 2 * self._result_bytes(op)            # read slice + write
        if oc in ("dynamic-update-slice", "scatter"):
            upd = self._name_bytes(op["operands"][1]) if len(op["operands"]) > 1 else 0
            return 3 * upd                               # read+write slice region (+update read)
        if oc == "fusion":
            # parameters that are only sliced inside the fused computation
            # contribute their sliced bytes, not the whole buffer.
            total = self._result_bytes(op)
            called = self._called(op)
            reads = self._fusion_param_reads(called[0]) if called else {}
            for idx, o in enumerate(op["operands"]):
                full = self._name_bytes(o)
                total += min(full, reads.get(idx, full))
            return total
        return self._operand_bytes(op) + self._result_bytes(op)

    def _fusion_param_reads(self, comp: str) -> dict:
        """param index -> bytes actually read inside a fused computation
        (slice results for params consumed only by slicing ops)."""
        if comp in getattr(self, "_param_reads_memo", {}):
            return self._param_reads_memo[comp]
        if not hasattr(self, "_param_reads_memo"):
            self._param_reads_memo = {}
        ops = self.computations.get(comp, [])
        param_idx: dict[str, int] = {}
        for op in ops:
            if op["opcode"] == "parameter":
                m = re.search(r"parameter\((\d+)\)", op["line"])
                if m:
                    param_idx[op["name"]] = int(m.group(1))
        reads: dict[int, int] = {}
        sliced_only: dict[int, bool] = {i: True for i in param_idx.values()}
        for op in ops:
            for o in op["operands"]:
                if o in param_idx:
                    i = param_idx[o]
                    if op["opcode"] in ("dynamic-slice", "gather", "slice"):
                        reads[i] = reads.get(i, 0) + self._result_bytes(op)
                    else:
                        sliced_only[i] = False
        out = {}
        for i, only in sliced_only.items():
            if only and i in reads:
                out[i] = reads[i]
        self._param_reads_memo[comp] = out
        return out

    def _dot_flops(self, op) -> float:
        out_elems = _result_elems(op["type"])
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op["line"])
        k = 1
        if m and op["operands"]:
            lhs_t = self.shapes.get(op["operands"][0], "")
            sh = _SHAPE_RE.search(lhs_t)
            if sh:
                dims = [int(x) for x in sh.group(2).split(",")] if sh.group(2) else []
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(dims):
                        k *= dims[idx]
        return 2.0 * out_elems * k

    def _called(self, op) -> list[str]:
        out = [name for _, name in _ATTR_COMP.findall(op["attrs"])]
        for names in _ATTR_BRANCHES.findall(op["attrs"]):
            out.extend(n.strip().lstrip("%") for n in names.split(",") if n.strip())
        return out

    # ------------------------------------------------------------------
    def profile(self) -> dict:
        """Top traffic/flop contributors by op_name metadata (the jaxpr
        source op), trip-count aware — the 'profiler' for §Perf iterations."""
        agg: dict[str, dict] = defaultdict(lambda: {"bytes": 0.0, "flops": 0.0})

        def walk(comp: str, mult: float, in_fusion: bool):
            for op in self.computations.get(comp, []):
                oc = op["opcode"]
                m = re.search(r'op_name="([^"]+)"', op["line"])
                tag = m.group(1).split(" ")[0] if m else oc
                tag = re.sub(r"\[.*", "", tag)
                if oc == "while":
                    t = mult
                    tm = _TRIP_RE.search(op["line"])
                    t = mult * (int(tm.group(1)) if tm else 1)
                    for c in self._called(op):
                        walk(c, t, in_fusion)
                elif oc == "fusion":
                    for c in self._called(op):
                        walk(c, mult, True)
                    if not in_fusion:
                        agg[tag]["bytes"] += self._traffic_bytes(op) * mult
                elif oc in ("call", "conditional", "async-start", "custom-call"):
                    for c in self._called(op):
                        walk(c, mult, in_fusion)
                else:
                    if oc == "dot":
                        agg[tag]["flops"] += self._dot_flops(op) * mult
                    elif oc in _ELEMENTWISE:
                        agg[tag]["flops"] += _result_elems(op["type"]) * mult
                    if not in_fusion and oc not in _NO_TRAFFIC:
                        agg[tag]["bytes"] += self._traffic_bytes(op) * mult

        walk(self.entry, 1.0, False)
        return dict(agg)

    def cost(self, comp: str | None = None, in_fusion: bool = False) -> dict:
        comp = comp or self.entry
        key = (comp, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = {"flops": 0.0, "bytes": 0.0,
                 "coll": defaultdict(lambda: {"bytes": 0.0, "traffic": 0.0, "count": 0})}
        for op in self.computations.get(comp, []):
            oc = op["opcode"]
            if oc == "while":
                called = self._called(op)
                trip = 1
                m = _TRIP_RE.search(op["line"])
                if m:
                    trip = int(m.group(1))
                else:
                    self.unknown_trips.append(f"{comp}/{op['name']}")
                for c in called:
                    sub = self.cost(c, in_fusion)
                    _acc(total, sub, trip)
                total["bytes"] += self._result_bytes(op)  # loop-carried io once
            elif oc == "fusion":
                for c in self._called(op):
                    sub = self.cost(c, True)
                    _acc(total, sub, 1)
                if not in_fusion:
                    total["bytes"] += self._traffic_bytes(op)
            elif oc in ("call", "conditional", "async-start", "custom-call"):
                subs = [self.cost(c, in_fusion) for c in self._called(op)]
                if subs:
                    if oc == "conditional":  # max over branches
                        best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                        _acc(total, best, 1)
                    else:
                        for sub in subs:
                            _acc(total, sub, 1)
            elif any(op["opcode"].startswith(c) for c in _COLLECTIVES):
                if op["opcode"].endswith("-done"):
                    continue
                kind = next(c for c in _COLLECTIVES if op["opcode"].startswith(c))
                b = self._result_bytes(op)
                s = _group_size(op["line"])
                e = total["coll"][kind]
                e["bytes"] += b
                e["traffic"] += _traffic(kind, b, s)
                e["count"] += 1
                if not in_fusion:
                    total["bytes"] += self._operand_bytes(op) + self._result_bytes(op)
            else:
                if oc == "dot":
                    total["flops"] += self._dot_flops(op)
                elif oc in ("reduce", "reduce-window"):
                    total["flops"] += self._operand_bytes(op) / 4.0  # ~1 flop/elem
                elif oc in _ELEMENTWISE:
                    total["flops"] += _result_elems(op["type"])
                if not in_fusion and oc not in _NO_TRAFFIC:
                    total["bytes"] += self._traffic_bytes(op)
        self._memo[key] = total
        return total


def _group_size(line: str) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def _acc(total, sub, mult):
    total["flops"] += sub["flops"] * mult
    total["bytes"] += sub["bytes"] * mult
    for k, v in sub["coll"].items():
        e = total["coll"][k]
        e["bytes"] += v["bytes"] * mult
        e["traffic"] += v["traffic"] * mult
        e["count"] += v["count"] * mult


def analyze(hlo_text: str) -> dict:
    mod = Module(hlo_text)
    c = mod.cost()
    coll = {k: dict(v) for k, v in c["coll"].items()}
    return {
        "flops": c["flops"],
        "bytes": c["bytes"],
        "collectives": coll,
        "collective_traffic": float(sum(v["traffic"] for v in coll.values())),
        "collective_count": int(sum(v["count"] for v in coll.values())),
        "unknown_trips": mod.unknown_trips[:20],
    }
