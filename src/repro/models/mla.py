"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Train/prefill materializes per-head K/V from the compressed latent c_kv
(kv_lora_rank wide) and runs ordinary blockwise attention. Decode uses the
**absorbed** form: W_uk is folded into the query and attention runs directly
against the [T, kv_lora + rope_dim] latent cache, so per-token cache cost is
O(kv_lora + d_rope) = 576 floats — the property that makes this arch
eligible for the long_500k shape (memory-sub-quadratic decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import blockwise_attention
from .common import ModelConfig, apply_rope, dense_init, rmsnorm


def init(key, cfg: ModelConfig):
    m = cfg.mla
    h = cfg.num_heads
    k = jax.random.split(key, 6)
    qdim = h * (m.nope_head_dim + m.rope_head_dim)
    p = {
        "wq": {"w": dense_init(k[0], (cfg.d_model, qdim), cfg.jdtype)},
        "w_dkv": {"w": dense_init(k[1], (cfg.d_model, m.kv_lora_rank + m.rope_head_dim), cfg.jdtype)},
        "kv_norm": {"w": jnp.ones((m.kv_lora_rank,), cfg.jdtype)},
        "w_uk": {"w": dense_init(k[2], (m.kv_lora_rank, h * m.nope_head_dim), cfg.jdtype)},
        "w_uv": {"w": dense_init(k[3], (m.kv_lora_rank, h * m.v_head_dim), cfg.jdtype)},
        "wo": {"w": dense_init(k[4], (h * m.v_head_dim, cfg.d_model), cfg.jdtype)},
    }
    return p


def _project_q(params, cfg, x, positions):
    m = cfg.mla
    h = cfg.num_heads
    b, t, _ = x.shape
    q = (x @ params["wq"]["w"]).reshape(b, t, h, m.nope_head_dim + m.rope_head_dim)
    qn, qr = jnp.split(q, [m.nope_head_dim], axis=-1)
    qr = apply_rope(qr.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    return qn, qr


def _latent(params, cfg, x, positions):
    m = cfg.mla
    ckv_kr = x @ params["w_dkv"]["w"]
    ckv, kr = jnp.split(ckv_kr, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(ckv, params["kv_norm"]["w"], cfg.norm_eps)
    kr = apply_rope(kr[:, :, None, :].transpose(0, 2, 1, 3), positions,
                    cfg.rope_theta).transpose(0, 2, 1, 3)[:, :, 0, :]
    return ckv, kr  # [b,t,kvr], [b,t,dr]


def apply_seq(params, cfg: ModelConfig, x, positions, *, return_cache=False,
              differentiable=False):
    """Full-sequence MLA. x: [b,t,d]. Returns out (+ latent cache)."""
    m = cfg.mla
    h = cfg.num_heads
    b, t, _ = x.shape
    qn, qr = _project_q(params, cfg, x, positions)
    ckv, kr = _latent(params, cfg, x, positions)
    kn = (ckv @ params["w_uk"]["w"]).reshape(b, t, h, m.nope_head_dim)
    v = (ckv @ params["w_uv"]["w"]).reshape(b, t, h, m.v_head_dim)
    qf = jnp.concatenate([qn, qr], axis=-1)
    kf = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], (b, t, h, m.rope_head_dim))], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    # v is narrower than the qk head width; pad v inside _attn_qkv.
    out = _attn_qkv(qf, kf, v, scale, cfg, differentiable)
    out = out.reshape(b, t, h * m.v_head_dim) @ params["wo"]["w"]
    if return_cache:
        return out, {"ckv": ckv, "kr": kr}
    return out


def _attn_qkv(qf, kf, v, scale: float, cfg, differentiable=False):
    """blockwise attention where v width differs from qk width: pad v."""
    dqk = qf.shape[-1]
    dv = v.shape[-1]
    if dv < dqk:
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv)))
    else:
        vpad = v
    out = blockwise_attention(qf, kf, vpad, causal=True, scale=scale,
                              q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
                              differentiable=differentiable)
    return out[..., :dv]


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), cfg.jdtype),
        "kr": jnp.zeros((batch, max_len, m.rope_head_dim), cfg.jdtype),
    }


def apply_decode(params, cfg: ModelConfig, x, cache, cache_len):
    """Absorbed-matrix single-token decode. x: [b, 1, d]."""
    m = cfg.mla
    h = cfg.num_heads
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    qn, qr = _project_q(params, cfg, x, pos)              # [b,1,h,dn], [b,1,h,dr]
    ckv_t, kr_t = _latent(params, cfg, x, pos)            # [b,1,kvr], [b,1,dr]
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t.astype(cache["ckv"].dtype), cache_len, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_t.astype(cache["kr"].dtype), cache_len, axis=1)

    # Absorb W_uk into the query: q_lat [b,h,kvr]
    wuk = params["w_uk"]["w"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_lat = jnp.einsum("bhd,khd->bhk", qn[:, 0].astype(jnp.float32),
                       wuk.transpose(0, 1, 2).astype(jnp.float32))
    s_len = ckv_cache.shape[1]
    scores = (
        jnp.einsum("bhk,bsk->bhs", q_lat, ckv_cache.astype(jnp.float32))
        + jnp.einsum("bhr,bsr->bhs", qr[:, 0].astype(jnp.float32), kr_cache.astype(jnp.float32))
    ) / jnp.sqrt(jnp.float32(m.nope_head_dim + m.rope_head_dim))
    mask = jnp.arange(s_len)[None, None, :] <= cache_len
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", w, ckv_cache.astype(jnp.float32))  # [b,h,kvr]
    wuv = params["w_uv"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhk,khd->bhd", ctx, wuv.astype(jnp.float32))
    out = o.reshape(b, 1 * h * m.v_head_dim).astype(x.dtype)[:, None, :]
    out = out.reshape(b, 1, h * m.v_head_dim) @ params["wo"]["w"]
    return out, {"ckv": ckv_cache, "kr": kr_cache}
