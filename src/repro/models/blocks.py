"""Transformer / hybrid block assembly.

Block kinds (cfg.block_kind): "attn" (attention or MLA + dense FFN),
"moe" (attention/MLA + MoE FFN), "mamba" (Mamba2), "shared_attn" (hybrid:
one shared attention+FFN block applied at intervals — Zamba2). Whisper's
encoder/decoder blocks live here too.

Every block has a uniform signature:
    apply(params, cfg, h, aux) -> (h, extras)
aux = {mode: train|prefill|decode, positions, cache (layer's entry or None),
cache_len, enc_out (whisper)}; extras = {cache: new entry} | {metrics...}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .attention import blockwise_attention
from .common import (
    ModelConfig, act_fn, apply_mrope, apply_rope, dense_init, layernorm, rmsnorm,
)


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def norm_init(cfg, d=None):
    d = d or cfg.d_model
    p = {"w": jnp.ones((d,), cfg.jdtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), cfg.jdtype)
    return p


def apply_norm(params, cfg, x):
    if cfg.norm == "layernorm":
        return layernorm(x, params["w"], params.get("b"), cfg.norm_eps)
    return rmsnorm(x, params["w"], cfg.norm_eps)


def mlp_init(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_glu:
        return {
            "w_gate": {"w": dense_init(ks[0], (d, f), cfg.jdtype)},
            "w_up": {"w": dense_init(ks[1], (d, f), cfg.jdtype)},
            "w_down": {"w": dense_init(ks[2], (f, d), cfg.jdtype)},
        }
    p = {
        "w_up": {"w": dense_init(ks[0], (d, f), cfg.jdtype)},
        "w_down": {"w": dense_init(ks[1], (f, d), cfg.jdtype)},
    }
    if cfg.proj_bias:
        p["w_up"]["b"] = jnp.zeros((f,), cfg.jdtype)
        p["w_down"]["b"] = jnp.zeros((d,), cfg.jdtype)
    return p


def mlp_apply(params, cfg, x):
    act = act_fn(cfg.act)
    if cfg.mlp_glu:
        h = act(x @ params["w_gate"]["w"]) * (x @ params["w_up"]["w"])
        return h @ params["w_down"]["w"]
    h = x @ params["w_up"]["w"]
    if "b" in params["w_up"]:
        h = h + params["w_up"]["b"]
    h = act(h)
    h = h @ params["w_down"]["w"]
    if "b" in params["w_down"]:
        h = h + params["w_down"]["b"]
    return h


# --------------------------------------------------------------------------
# Attention (GQA/MQA/MHA) with KV cache
# --------------------------------------------------------------------------

def attn_init(key, cfg):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": {"w": dense_init(ks[0], (d, h * hd), cfg.jdtype)},
        "wk": {"w": dense_init(ks[1], (d, hkv * hd), cfg.jdtype)},
        "wv": {"w": dense_init(ks[2], (d, hkv * hd), cfg.jdtype)},
        "wo": {"w": dense_init(ks[3], (h * hd, d), cfg.jdtype)},
    }
    if cfg.qkv_bias:
        p["wq"]["b"] = jnp.zeros((h * hd,), cfg.jdtype)
        p["wk"]["b"] = jnp.zeros((hkv * hd,), cfg.jdtype)
        p["wv"]["b"] = jnp.zeros((hkv * hd,), cfg.jdtype)
    if cfg.proj_bias:
        p["wo"]["b"] = jnp.zeros((d,), cfg.jdtype)
    return p


def _proj(p, x):
    y = x @ p["w"]
    return y + p["b"] if "b" in p else y


def _pos_embed_qk(cfg, q, k, positions):
    # q/k: [b, t, H, hd]; positions: [b, t] or [b, 3, t] for mrope
    if cfg.pos == "rope":
        q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    elif cfg.pos == "mrope":
        q = apply_mrope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = apply_mrope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    return q, k


def attn_apply(params, cfg, x, aux, *, causal=True, kv_override=None):
    """Unified attention: train (no cache), prefill (fills cache), decode
    (single token against cache), cross (kv_override = encoder states)."""
    b, t, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    mode = aux["mode"]
    q = _proj(params["wq"], x).reshape(b, t, h, hd)

    if kv_override is not None:  # cross-attention (whisper decoder)
        xs = kv_override
        k = _proj(params["wk"], xs).reshape(b, xs.shape[1], hkv, hd)
        v = _proj(params["wv"], xs).reshape(b, xs.shape[1], hkv, hd)
        out = blockwise_attention(q, k, v, causal=False,
                                  q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        extras = {}
    else:
        k = _proj(params["wk"], x).reshape(b, t, hkv, hd)
        v = _proj(params["wv"], x).reshape(b, t, hkv, hd)
        if cfg.pos in ("rope", "mrope"):
            q, k = _pos_embed_qk(cfg, q, k, aux["positions"])
        if mode == "train":
            out = blockwise_attention(q, k, v, causal=causal,
                                      q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
                                      differentiable=True)
            extras = {}
        elif mode == "prefill":
            cache = aux["cache"]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            out = blockwise_attention(q, k, v, causal=causal,
                                      q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
            extras = {"cache": {"k": ck, "v": cv}}
        else:  # decode
            cache = aux["cache"]
            clen = aux["cache_len"]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), clen, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), clen, axis=1)
            out = blockwise_attention(q, ck, cv, causal=causal,
                                      q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
                                      kv_len=clen + 1)
            extras = {"cache": {"k": ck, "v": cv}}
    out = out.reshape(b, t, h * hd) @ params["wo"]["w"]
    if "b" in params["wo"]:
        out = out + params["wo"]["b"]
    return out, extras


def attn_cache_init(cfg, batch, max_len):
    hkv, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), cfg.jdtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), cfg.jdtype),
    }


# --------------------------------------------------------------------------
# Full blocks
# --------------------------------------------------------------------------

def block_init(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"ln1": norm_init(cfg), "mamba": ssm_mod.init(ks[0], cfg)}
    p = {"ln1": norm_init(cfg)}
    if cfg.mla is not None:
        p["attn"] = mla_mod.init(ks[0], cfg)
    else:
        p["attn"] = attn_init(ks[0], cfg)
    p["ln2"] = norm_init(cfg)
    if kind == "moe":
        p["ffn"] = moe_mod.init(ks[1], cfg)
    else:
        p["ffn"] = mlp_init(ks[1], cfg)
    return p


def block_apply(params, cfg, h, aux, kind: str):
    extras = {}
    if kind == "mamba":
        x = apply_norm(params["ln1"], cfg, h)
        if aux["mode"] == "decode":
            y, new_cache = ssm_mod.apply_decode(params["mamba"], cfg, x, aux["cache"])
            extras["cache"] = new_cache
        else:
            y = ssm_mod.apply_seq(params["mamba"], cfg, x)
            if aux["mode"] == "prefill":
                # Prefill for SSM: recompute final state for the cache.
                extras["cache"] = ssm_prefill_cache(params["mamba"], cfg, x)
        return h + y, extras

    x = apply_norm(params["ln1"], cfg, h)
    if cfg.mla is not None:
        if aux["mode"] == "train":
            y = mla_mod.apply_seq(params["attn"], cfg, x, aux["positions"],
                                  differentiable=True)
        elif aux["mode"] == "prefill":
            y, latent = mla_mod.apply_seq(params["attn"], cfg, x, aux["positions"], return_cache=True)
            cache = aux["cache"]
            ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], latent["ckv"].astype(cache["ckv"].dtype), 0, axis=1)
            kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], latent["kr"].astype(cache["kr"].dtype), 0, axis=1)
            extras["cache"] = {"ckv": ckv, "kr": kr}
        else:
            y, new_cache = mla_mod.apply_decode(params["attn"], cfg, x, aux["cache"], aux["cache_len"])
            extras["cache"] = new_cache
    else:
        y, a_extras = attn_apply(params["attn"], cfg, x, aux)
        extras.update(a_extras)
    h = h + y

    x = apply_norm(params["ln2"], cfg, h)
    if kind == "moe":
        y, metrics = moe_mod.apply(params["ffn"], cfg, x)
        extras["metrics"] = metrics
    else:
        y = mlp_apply(params["ffn"], cfg, x)
    return h + y, extras


def ssm_prefill_cache(mamba_params, cfg, x):
    """Compute the post-sequence SSM state + conv tail for decode."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_mod.dims(cfg)
    zxbcdt = x @ mamba_params["in_proj"]["w"]
    z, xraw, Braw, Craw, dt = ssm_mod._split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xraw, Braw, Craw], axis=-1)
    conv_out = jax.nn.silu(ssm_mod._conv1d(conv_in, mamba_params["conv"]["w"], mamba_params["conv"]["b"]))
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    bsz, t, _ = x.shape
    xh = xs.reshape(bsz, t, nheads, s.head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + mamba_params["dt_bias"])
    A = -jnp.exp(mamba_params["A_log"])
    _, final_state = ssm_mod.ssd_scan(xh, dtp, A, B, C, s.chunk)
    conv_tail = conv_in[:, -(s.d_conv - 1):, :].astype(jnp.float32)
    return {"state": final_state, "conv": conv_tail}


def block_cache_init(cfg, kind: str, batch: int, max_len: int):
    if kind == "mamba":
        return ssm_mod.init_cache(cfg, batch)
    if cfg.mla is not None:
        return mla_mod.init_cache(cfg, batch, max_len)
    return attn_cache_init(cfg, batch, max_len)
