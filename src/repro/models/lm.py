"""Full language models: embedding -> scanned block stack -> norm -> head.

Layers are grouped into *segments* of consecutive identical block kinds
(dense runs, MoE runs, Mamba runs between shared-attention applications);
each segment's parameters are stacked on a leading axis and executed with
``lax.scan`` (+ per-layer ``jax.checkpoint`` when cfg.remat) so the HLO stays
small for 80-layer x 512-device compiles and activation memory stays at
O(num_checkpoints).

Zamba2-style hybrids share ONE attention block's parameters across all its
application points (cfg.attn_every); each application point still owns its
own KV-cache entry. Whisper is encoder-decoder: encoder = non-causal blocks
over stub frame embeddings, decoder = causal self-attention + cross-attention
with a precomputed encoder K/V cache.
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp

from . import blocks as B
from .common import ModelConfig, embed_init, sinusoid_positions


# --------------------------------------------------------------------------
# Segments
# --------------------------------------------------------------------------

def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    kinds = [cfg.block_kind(i) for i in range(cfg.num_layers)]
    return [(k, len(list(g))) for k, g in itertools.groupby(kinds)]


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params = {"embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.jdtype)}
    segs = segments(cfg)
    seg_params = []
    lkeys = jax.random.split(ks[1], sum(n for _, n in segs) + 1)
    li = 0
    shared_made = False
    for kind, n in segs:
        if kind == "shared_attn":
            if not shared_made:
                params["shared_attn"] = B.block_init(ks[2], cfg, "attn")
                shared_made = True
            seg_params.append(None)  # parameters live in params["shared_attn"]
            li += n
        else:
            seg_params.append(_stack([B.block_init(lkeys[li + i], cfg, kind) for i in range(n)]))
            li += n
    params["segments"] = seg_params
    params["final_norm"] = B.norm_init(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[3], (cfg.d_model, cfg.padded_vocab), cfg.jdtype)
    if cfg.encdec is not None:
        enc_keys = jax.random.split(ks[4], cfg.encdec.enc_layers)
        params["encoder"] = {
            "blocks": _stack([B.block_init(k, cfg, "attn") for k in enc_keys]),
            "final_norm": B.norm_init(cfg),
        }
        xk = jax.random.split(ks[5], cfg.num_layers)
        params["cross_attn"] = _stack(
            [{"ln": B.norm_init(cfg), "attn": B.attn_init(k, cfg)} for k in xk]
        )
    return params


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init(k, cfg), jax.random.key(0))
    import numpy as np
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.block_kind(i) == "moe")
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = n_moe * (m.num_experts - m.top_k) * per_expert
    return int(total - inactive)


# --------------------------------------------------------------------------
# Positions
# --------------------------------------------------------------------------

def make_positions(cfg, b, t, offset=0):
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None] + offset, (b, t))
    if cfg.pos == "mrope":
        return jnp.broadcast_to(pos[:, None, :], (b, 3, t))  # text: t==h==w ids
    return pos


# --------------------------------------------------------------------------
# Stack execution
# --------------------------------------------------------------------------

def _run_segment(seg_p, cfg, kind, h, aux, seg_cache):
    """Scan one segment. seg_cache: stacked per-layer cache or None."""
    mode = aux["mode"]
    has_cache = seg_cache is not None

    def body(carry, xs):
        p_i, c_i = xs
        a = dict(aux)
        a["cache"] = c_i
        out, extras = B.block_apply(p_i, cfg, carry, a, kind)
        ys = (extras.get("cache"), extras.get("metrics", {"moe_aux": jnp.float32(0), "moe_dropped": jnp.float32(0)}) if kind == "moe" else None)
        return out, ys

    fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    h, ys = jax.lax.scan(fn, h, (seg_p, seg_cache))
    new_cache, metrics = ys
    msum = None
    if kind == "moe":
        msum = jax.tree.map(jnp.sum, metrics)
    return h, (new_cache if has_cache else None), msum


def _apply_stack(params, cfg, h, aux, cache):
    """Run all segments. cache: list aligned with segments (entries None in
    train mode)."""
    segs = segments(cfg)
    new_cache = []
    metrics = {"moe_aux": jnp.float32(0), "moe_dropped": jnp.float32(0)}
    for si, (kind, n) in enumerate(segs):
        seg_cache = cache[si] if cache is not None else None
        if kind == "shared_attn":
            # n applications of the single shared block, each with its own cache.
            sc_list = []
            for j in range(n):
                a = dict(aux)
                a["cache"] = jax.tree.map(lambda x: x[j], seg_cache) if seg_cache is not None else None
                h, extras = B.block_apply(params["shared_attn"], cfg, h, a, "attn")
                sc_list.append(extras.get("cache"))
            new_cache.append(_stack(sc_list) if seg_cache is not None else None)
        else:
            h, nc, ms = _run_segment(params["segments"][si], cfg, kind, h, aux, seg_cache)
            new_cache.append(nc)
            if ms is not None:
                metrics = jax.tree.map(jnp.add, metrics, ms)
    return h, new_cache, metrics


# --------------------------------------------------------------------------
# Public API: train forward / prefill / decode
# --------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return h


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None, positions=None):
    """Training/scoring forward -> (hidden [b,t,d], metrics)."""
    h = embed_tokens(params, cfg, tokens) if embeds is None else embeds.astype(cfg.jdtype)
    b, t, _ = h.shape
    if cfg.pos == "sinusoid":
        h = h + sinusoid_positions(t, cfg.d_model).astype(h.dtype)[None]
    if positions is None:
        positions = make_positions(cfg, b, t)
    aux = {"mode": "train", "positions": positions, "cache": None, "cache_len": None}
    h, _, metrics = _apply_stack(params, cfg, h, aux, None)
    h = B.apply_norm(params["final_norm"], cfg, h)
    return h, metrics


def logits_fn(params, cfg, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ head).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask pad columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    out = []
    for kind, n in segments(cfg):
        k = "attn" if kind == "shared_attn" else kind
        out.append(_stack([B.block_cache_init(cfg, k, batch, max_len) for _ in range(n)]))
    return out


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None, cache):
    """Fill the cache with a full prompt; returns (last-token logits, cache)."""
    h = embed_tokens(params, cfg, tokens) if embeds is None else embeds.astype(cfg.jdtype)
    b, t, _ = h.shape
    if cfg.pos == "sinusoid":
        h = h + sinusoid_positions(t, cfg.d_model).astype(h.dtype)[None]
    aux = {"mode": "prefill", "positions": make_positions(cfg, b, t), "cache_len": t}
    h, new_cache, _ = _apply_stack(params, cfg, h, aux, cache)
    h = B.apply_norm(params["final_norm"], cfg, h)
    return logits_fn(params, cfg, h[:, -1]), new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, cache_len):
    """One decode step. tokens: [b] int32; cache_len: [] int32 (tokens already
    in cache). Returns (logits [b, V], new cache)."""
    h = embed_tokens(params, cfg, tokens[:, None])
    b = h.shape[0]
    if cfg.pos == "sinusoid":
        h = h + _sinusoid_at(cache_len, cfg.d_model).astype(h.dtype)[None, None, :]
    pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32)[None, None], (b, 1))
    if cfg.pos == "mrope":
        pos = jnp.broadcast_to(pos[:, None, :], (b, 3, 1))
    aux = {"mode": "decode", "positions": pos, "cache_len": cache_len}
    h, new_cache, _ = _apply_stack(params, cfg, h, aux, cache)
    h = B.apply_norm(params["final_norm"], cfg, h)
    return logits_fn(params, cfg, h[:, -1]), new_cache


def _sinusoid_at(pos, d):
    import numpy as np
    div = jnp.asarray(np.exp(-np.log(10000.0) * np.arange(0, d, 2, dtype=np.float32) / d))
    ang = jnp.float32(pos) * div
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


# --------------------------------------------------------------------------
# Whisper-style encoder-decoder
# --------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """frames: [b, t_enc, d_model] stub frame embeddings -> encoder states."""
    h = frames.astype(cfg.jdtype) + sinusoid_positions(frames.shape[1], cfg.d_model).astype(cfg.jdtype)[None]
    aux = {"mode": "train", "positions": None, "cache": None, "cache_len": None}

    def body(carry, p_i):
        x = B.apply_norm(p_i["ln1"], cfg, carry)
        y, _ = B.attn_apply(p_i["attn"], cfg, x, aux, causal=False)
        carry = carry + y
        x = B.apply_norm(p_i["ln2"], cfg, carry)
        return carry + B.mlp_apply(p_i["ffn"], cfg, x), None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, params["encoder"]["blocks"])
    return B.apply_norm(params["encoder"]["final_norm"], cfg, h)


def _decoder_stack(params, cfg, h, aux, enc_out, cache, xcache):
    """Decoder = self-attn blocks interleaved with cross-attention. The block
    stack is the standard one; cross-attention applies after each block's
    self-attention using params['cross_attn'][layer]."""
    segs = segments(cfg)
    assert len(segs) == 1 and segs[0][0] == "attn", "whisper decoder is dense"
    seg_p = params["segments"][0]
    xp = params["cross_attn"]
    mode = aux["mode"]

    def body(carry, xs):
        p_i, c_i, xp_i, xc_i = xs
        a = dict(aux)
        a["cache"] = c_i
        # self-attention + (cross) + mlp, hand-rolled to interleave cross-attn
        x = B.apply_norm(p_i["ln1"], cfg, carry)
        y, ex = B.attn_apply(p_i["attn"], cfg, x, a)
        carry = carry + y
        x = B.apply_norm(xp_i["ln"], cfg, carry)
        if mode == "decode":
            q = B._proj(xp_i["attn"]["wq"], x).reshape(x.shape[0], 1, cfg.num_heads, cfg.hd)
            from .attention import blockwise_attention
            y = blockwise_attention(q, xc_i["k"], xc_i["v"], causal=False,
                                    q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
            y = y.reshape(x.shape[0], 1, cfg.num_heads * cfg.hd) @ xp_i["attn"]["wo"]["w"]
            if "b" in xp_i["attn"]["wo"]:
                y = y + xp_i["attn"]["wo"]["b"]
            new_xc = xc_i
        else:
            y, _ = B.attn_apply(xp_i["attn"], cfg, x, a, kv_override=enc_out)
            if mode == "prefill":
                hkv, hd = cfg.num_kv_heads, cfg.hd
                bb = enc_out.shape[0]
                new_xc = {
                    "k": B._proj(xp_i["attn"]["wk"], enc_out).reshape(bb, -1, hkv, hd),
                    "v": B._proj(xp_i["attn"]["wv"], enc_out).reshape(bb, -1, hkv, hd),
                }
            else:
                new_xc = None
        carry = carry + y
        x = B.apply_norm(p_i["ln2"], cfg, carry)
        carry = carry + B.mlp_apply(p_i["ffn"], cfg, x)
        return carry, (ex.get("cache"), new_xc)

    fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    h, ys = jax.lax.scan(fn, h, (seg_p, cache, xp, xcache))
    return h, ys


def forward_encdec(params, cfg: ModelConfig, frames, dec_tokens):
    """Training forward for whisper: returns (decoder hidden, metrics)."""
    enc_out = encode(params, cfg, frames)
    h = embed_tokens(params, cfg, dec_tokens)
    t = dec_tokens.shape[1]
    h = h + sinusoid_positions(t, cfg.d_model).astype(h.dtype)[None]
    aux = {"mode": "train", "positions": make_positions(cfg, dec_tokens.shape[0], t),
           "cache": None, "cache_len": None}
    n = cfg.num_layers
    h, _ = _decoder_stack(params, cfg, h, aux, enc_out,
                          cache=_none_caches(cfg, n), xcache=_none_caches(cfg, n))
    h = B.apply_norm(params["final_norm"], cfg, h)
    return h, {}


def _none_caches(cfg, n):
    # scan requires an xs pytree; use zero-size placeholders
    return jnp.zeros((n, 0), jnp.float32)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_dec_len: int, enc_len: int):
    n = cfg.num_layers
    self_cache = _stack([B.attn_cache_init(cfg, batch, max_dec_len) for _ in range(n)])
    xcache = _stack([
        {"k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), cfg.jdtype),
         "v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), cfg.jdtype)}
        for _ in range(n)
    ])
    return {"self": self_cache, "cross": xcache}


def prefill_encdec(params, cfg: ModelConfig, frames, dec_tokens, cache):
    enc_out = encode(params, cfg, frames)
    h = embed_tokens(params, cfg, dec_tokens)
    b, t = dec_tokens.shape
    h = h + sinusoid_positions(t, cfg.d_model).astype(h.dtype)[None]
    aux = {"mode": "prefill", "positions": make_positions(cfg, b, t), "cache_len": t}
    h, ys = _decoder_stack(params, cfg, h, aux, enc_out,
                           cache=cache["self"], xcache=_none_caches(cfg, cfg.num_layers))
    new_self, new_cross = ys
    h = B.apply_norm(params["final_norm"], cfg, h)
    return logits_fn(params, cfg, h[:, -1]), {"self": new_self, "cross": new_cross}


def decode_step_encdec(params, cfg: ModelConfig, tokens, cache, cache_len):
    h = embed_tokens(params, cfg, tokens[:, None])
    b = h.shape[0]
    h = h + _sinusoid_at(cache_len, cfg.d_model).astype(h.dtype)[None, None, :]
    pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32)[None, None], (b, 1))
    aux = {"mode": "decode", "positions": pos, "cache_len": cache_len}
    h, ys = _decoder_stack(params, cfg, h, aux, None,
                           cache=cache["self"], xcache=cache["cross"])
    new_self, _ = ys
    h = B.apply_norm(params["final_norm"], cfg, h)
    return logits_fn(params, cfg, h[:, -1]), {"self": new_self, "cross": cache["cross"]}
