"""Model config schema, parameter init helpers, norms, activations, RoPE.

No flax on the box — parameters are nested dicts of jnp arrays, modules are
(init, apply) function pairs. Everything is deliberately explicit so the
sharding rules in :mod:`repro.distributed.sharding` can pattern-match on
parameter paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Config schema
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading layers use a dense FFN (DeepSeek)
    # capacity-cut policy: "criticality" keeps the highest-router-weight
    # assignments per expert (the paper's criticality-ordered scheduling,
    # token->expert edition); "arrival" is FCFS token order (the in-order
    # baseline). Ablation in tests/test_moe.py.
    dispatch_order: str = "criticality"
    # Pin the dispatch tensor's expert dim to the model axis. Fixes a 16x
    # dispatch-traffic replication (see EXPERIMENTS §Perf B1) but provokes an
    # SPMD reshard-matmul of equal cost on this XLA version — net neutral,
    # default off; also trips a jax-0.8 batched-gather transpose bug under
    # grad, so only ever enabled for serve paths.
    ep_constraint: bool = False


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: int = 0         # 0 == full-rank queries (V2-Lite)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128             # SSD chunk length
    compute_dtype: str = "float32"  # SSD einsum operand dtype (bf16 = §Perf)


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int
    dec_ratio: int = 8           # decoder len = seq_len // dec_ratio (shapes)
    frontend: str = "stub"       # conv frontend stubbed: input = frame embeds


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    qkv_bias: bool = False
    proj_bias: bool = False      # biases on out-proj and MLP (whisper)
    mlp_glu: bool = True         # gated MLP; False = plain 2-matrix MLP
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    pos: str = "rope"            # rope | mrope | sinusoid | none
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    encdec: Optional[EncDecCfg] = None
    attn_every: int = 0          # hybrid: shared attn block every k layers
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024       # blockwise attention kv-chunk length
    loss_chunk: int = 2048       # chunked cross-entropy sequence chunk
    scan_layers: bool = True
    fsdp: bool = False           # shard params+opt over the data axis too
    grad_accum: int = 1          # microbatch accumulation in train_step
    vocab_pad_to: int = 256      # embedding tables padded for TP divisibility

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m if m else self.vocab_size

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: per-token decode state is O(1) or O(rank)."""
        return self.ssm is not None or self.mla is not None

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def block_kind(self, i: int) -> str:
        """Block type of layer i: attn | moe | mamba | shared_attn."""
        if self.ssm is not None and self.attn_every == 0:
            return "mamba"
        if self.ssm is not None:
            return "shared_attn" if (i + 1) % self.attn_every == 0 else "mamba"
        if self.moe is not None:
            return "attn" if i < self.moe.first_dense_layers else "moe"
        return "attn"



# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------

def rmsnorm(x, weight, eps):
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------------
# Rotary position embeddings (RoPE / M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, H, T, D], positions: [B, T] int32 -> rotated x."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                 # [D/2]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,T,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


MROPE_SECTIONS = (16, 24, 24)  # qwen2-vl: (temporal, height, width) freq split


def apply_mrope(x, positions3, theta: float, sections=MROPE_SECTIONS):
    """Multimodal RoPE: positions3 [B, 3, T] (t/h/w ids). For text tokens the
    three ids are equal and M-RoPE reduces numerically to 1-D RoPE."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.asarray(rope_freqs(d, theta))                 # [half]
    # Each frequency channel is driven by one of the three position streams.
    sec = np.zeros(half, dtype=np.int32)
    bounds = np.cumsum(sections)
    for i in range(half):
        sec[i] = int(np.searchsorted(bounds, i % bounds[-1], side="right"))
    sec = jnp.asarray(np.minimum(sec, 2))
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                       # [B, 3, T]
        jnp.broadcast_to(sec[None, :, None], (positions3.shape[0], half, 1)).astype(jnp.int32) * 0
        + sec[None, :, None].astype(jnp.int32),
        axis=1,
    )  # -> [B, half, T]
    ang = pos.transpose(0, 2, 1)[:, None, :, :] * freqs        # [B,1,T,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(t: int, d: int, offset: int = 0):
    pos = np.arange(offset, offset + t, dtype=np.float32)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, d, 2, dtype=np.float32) / d)
    pe = np.zeros((t, d), dtype=np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)
