"""Portable blockwise (flash) attention in pure jnp.

This is the model stack's attention on every backend: online-softmax over KV
chunks, so the [Tq, Tkv] score matrix never materializes — O(cq * ck) live
scores per step. On TPU the Pallas kernel (:mod:`repro.kernels.flash_attention`)
is the drop-in hot-spot replacement; this implementation is also its
semantic twin and lowers under pjit/SPMD for the multi-pod dry-run.

Layout: q [B, Tq, Hq, D], k/v [B, Tkv, Hkv, D] (token-major, GQA by head
grouping — KV heads are never materialized ``rep`` times). Causal masking is
ends-aligned (decode convention); ``kv_len`` optionally bounds valid cache
positions per batch row. The causal inner loop has a *dynamic* trip count
(``fori_loop`` up to the diagonal chunk), so no FLOPs are spent on fully
masked blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def blockwise_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, scale: float | None = None,
    q_chunk: int = 512, kv_chunk: int = 1024,
    kv_len: jax.Array | None = None,
    differentiable: bool = False,
) -> jax.Array:
    """Returns [B, Tq, Hq, D] attention output (dtype of q, f32 accumulation).

    kv_len: optional [] or [B] int32 — number of valid kv positions (cache
    fill level). Defaults to Tkv. Causal alignment: the last q token attends
    up to kv position ``kv_len - 1``.

    differentiable=True (training): the q-chunk loop is Python-unrolled and
    each chunk scans a *statically bounded* number of KV chunks (reverse-mode
    safe, still no FLOPs on fully-masked causal blocks). False (inference):
    rolled ``lax.map`` over q chunks with a dynamic-trip-count inner loop.
    """
    b, tq, hq, d = q.shape
    _, tkv, hkv, _ = k.shape
    assert hq % hkv == 0
    rep = hq // hkv
    s = scale if scale is not None else 1.0 / (d ** 0.5)

    if kv_len is None:
        kv_len_b = jnp.full((b,), tkv, jnp.int32)
    else:
        kv_len_b = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))

    cq = min(q_chunk, tq)
    ck = min(kv_chunk, tkv)
    qpad = -tq % cq
    kpad = -tkv % ck
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    tq_p, tkv_p = tq + qpad, tkv + kpad
    nq, nk = tq_p // cq, tkv_p // ck

    # [B, Tkv, Hkv, D] -> [B, Hkv, Tkv, D] once (contiguous chunk slices).
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    qT = q.transpose(0, 2, 1, 3).reshape(b, hkv, rep, tq_p, d)

    offset = kv_len_b - tq  # ends-aligned causal offset, [B]

    def q_block(iq, qc):
        # qc: [B, Hkv, rep, cq, D]
        qpos = iq * cq + jnp.arange(cq, dtype=jnp.int32)            # [cq]
        qpos_b = qpos[None, :] + offset[:, None]                     # [B, cq]

        def kv_step(jk, carry):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(kT, jk * ck, ck, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vT, jk * ck, ck, axis=2)
            sc = jnp.einsum(
                "bgrqd,bgkd->bgrqk", qc.astype(jnp.float32),
                kc.astype(jnp.float32), preferred_element_type=jnp.float32,
            ) * s                                                    # [B,G,R,cq,ck]
            kpos = jk * ck + jnp.arange(ck, dtype=jnp.int32)         # [ck]
            valid = kpos[None, :] < kv_len_b[:, None]                # [B, ck]
            mask = valid[:, None, :]                                 # [B, 1, ck]
            if causal:
                mask = mask & (qpos_b[:, :, None] >= kpos[None, None, :])
            sc = jnp.where(mask[:, None, None, :, :], sc, NEG)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return m_new, l_new, acc_new

        m0 = jnp.full((b, hkv, rep, cq), NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, cq, d), jnp.float32)
        if n_static is not None:
            def scan_step(carry, jk):
                return kv_step(jk, carry), None
            (m, l, acc), _ = jax.lax.scan(
                scan_step, (m0, l0, a0), jnp.arange(n_static, dtype=jnp.int32))
        elif causal:
            # Last kv chunk this q block can see (dynamic trip count).
            hi_pos = (iq + 1) * cq - 1 + jnp.max(offset)
            n_need = jnp.clip(hi_pos // ck + 1, 0, nk)
            m, l, acc = jax.lax.fori_loop(0, n_need, kv_step, (m0, l0, a0))
        else:
            m, l, acc = jax.lax.fori_loop(0, nk, kv_step, (m0, l0, a0))
        return (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)

    if differentiable:
        # Python-unrolled q loop; static per-chunk kv bound (reverse-mode safe).
        qs = qT.reshape(b, hkv, rep, nq, cq, d)
        outs = []
        import math as _math
        # static upper bound on offset: kv_len <= tkv
        for i in range(nq):
            if causal:
                n_static = min(nk, _math.ceil(((i + 1) * cq + (tkv - tq)) / ck))
                n_static = max(n_static, 1)
            else:
                n_static = nk
            outs.append(q_block(jnp.int32(i), qs[:, :, :, i]))
        out = jnp.stack(outs, axis=3)                                # [B,G,R,nq,cq,D]
        out = out.reshape(b, hkv, rep, tq_p, d)
    elif nq == 1:
        n_static = None
        out = q_block(jnp.int32(0), qT)                              # [B,G,R,cq,D]
    else:
        n_static = None
        qs = qT.reshape(b, hkv, rep, nq, cq, d).transpose(3, 0, 1, 2, 4, 5)
        out = jax.lax.map(lambda args: q_block(args[0], args[1]),
                          (jnp.arange(nq, dtype=jnp.int32), qs))
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, rep, tq_p, d)
    out = out.reshape(b, hq, tq_p, d)
    return out.transpose(0, 2, 1, 3)[:, :tq]
