"""Mixture-of-Experts FFN with capacity-based grouped dispatch.

TPU/SPMD design (see DESIGN.md §6): activations are model-axis-replicated
under 2D TP+DP sharding, so per-group dispatch (top-k -> position-in-expert
-> gather to [G, E, C, d]) is **local** on every device; the expert dimension
E is sharded over the model axis (expert parallelism), and the weighted
combine scatter-add produces a model-partial result that SPMD completes with
the same all-reduce a tensor-parallel FFN needs — EP rides the TP collective,
no explicit all-to-all required.

This is also where the paper's technique integrates with the LM stack:
token->expert dispatch is a dataflow-firing problem, and the dispatch order
within a group is *criticality-ordered* (expert load = criticality), the
direct analogue of the paper's criticality-sorted ready-node memory: the
position-in-expert ranking that decides which tokens survive the capacity
cut processes the most-loaded (most critical) experts' tokens first.

Groups are sequences (G == batch), so groups distribute evenly over the data
axis. Capacity C = ceil(top_k * T * capacity_factor / E); overflow drops
(standard Switch-style), counted in metrics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, act_fn, dense_init


def _constrain_ep(x):
    """Pin the expert dimension (axis 1 of [G, E, C, ...]) to the "model"
    mesh axis. Without this, SPMD may materialize dispatch tensors
    replicated across the model axis (observed 16x traffic on dbrx); a
    no-op when no mesh with a "model" axis is active (smoke tests)."""
    try:
        from jax.sharding import PartitionSpec as P  # noqa: PLC0415
        spec = P(*([None, "model"] + [None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init(key, cfg: ModelConfig):
    m = cfg.moe
    k = jax.random.split(key, 5)
    e, d, f = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": {"w": dense_init(k[0], (d, e), jnp.float32)},
        "w_gate": dense_init(k[1], (e, d, f), cfg.jdtype),
        "w_up": dense_init(k[2], (e, d, f), cfg.jdtype),
        "w_down": dense_init(k[3], (e, f, d), cfg.jdtype),
    }
    if m.num_shared:
        fs = m.num_shared * m.d_ff_expert
        ks = jax.random.split(k[4], 3)
        p["shared"] = {
            "w_gate": {"w": dense_init(ks[0], (d, fs), cfg.jdtype)},
            "w_up": {"w": dense_init(ks[1], (d, fs), cfg.jdtype)},
            "w_down": {"w": dense_init(ks[2], (fs, d), cfg.jdtype)},
        }
    return p


def apply(params, cfg: ModelConfig, x):
    """x: [b, t, d] -> ([b, t, d], metrics dict)."""
    m = cfg.moe
    act = act_fn(cfg.act)
    g, t, d = x.shape  # groups == sequences
    e, k = m.num_experts, m.top_k
    cap = max(1, math.ceil(k * t * m.capacity_factor / e))
    cap = min(cap, t * k)

    logits = (x.astype(jnp.float32) @ params["router"]["w"])          # [g,t,e]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                              # [g,t,k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # --- position-in-expert (the capacity cut) ---
    fe = topi.reshape(g, t * k)                                       # assignments
    fw = topw.reshape(g, t * k)
    if m.dispatch_order == "criticality":
        # rank assignments per expert by DESCENDING router weight: under
        # capacity pressure the least-critical tokens drop, the direct
        # analogue of the paper's criticality-sorted ready-node memory.
        key = fe.astype(jnp.float32) * 2.0 + (1.0 - jax.lax.stop_gradient(fw))
        order = jnp.argsort(key, axis=1)                              # [g,tk]
        fe_srt = jnp.take_along_axis(fe, order, axis=1)
        oh = jax.nn.one_hot(fe_srt, e, dtype=jnp.int32)
        pos_srt = jnp.take_along_axis(
            jnp.cumsum(oh, axis=1) - 1, fe_srt[..., None], axis=-1)[..., 0]
        # invert the permutation with a GATHER (a batched scatter here trips
        # a jax-0.8 transpose bug under grad): mypos[i] = pos_srt[inv[i]].
        inv = jnp.argsort(order, axis=1)
        mypos = jnp.take_along_axis(pos_srt, inv, axis=1)
    else:  # "arrival": FCFS in token order (in-order baseline)
        oh = jax.nn.one_hot(fe, e, dtype=jnp.int32)                   # [g,tk,e]
        pos = jnp.cumsum(oh, axis=1) - 1                              # running count
        mypos = jnp.take_along_axis(pos, fe[..., None], axis=-1)[..., 0]
    keep = mypos < cap
    dropped = jnp.sum(~keep)

    tok = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(t * k)
    # dispatch tables: token index + combine weight per (expert, slot).
    # Dropped assignments get an out-of-bounds expert id -> mode="drop".
    gidx = jnp.arange(g)[:, None]
    drop_e = jnp.where(keep, fe, e)
    idx_table = jnp.full((g, e, cap), t, jnp.int32)                   # t == pad row
    idx_table = idx_table.at[gidx, drop_e, mypos].set(
        jnp.broadcast_to(tok[None, :], (g, t * k)), mode="drop")
    w_table = jnp.zeros((g, e, cap), jnp.float32)
    w_table = w_table.at[gidx, drop_e, mypos].set(fw, mode="drop")

    xpad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, None, :, :], idx_table[..., None].clip(0, t), axis=2
    )                                                                  # [g,e,cap,d]
    if m.ep_constraint:
        xe = _constrain_ep(xe)  # see MoECfg.ep_constraint / EXPERIMENTS §Perf B1

    h = act(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, params["w_up"])
    oe = jnp.einsum("gecf,efd->gecd", h, params["w_down"])             # [g,e,cap,d]
    oe = oe * w_table[..., None].astype(oe.dtype)

    out = jnp.zeros((g, t + 1, d), oe.dtype)
    out = out.at[gidx[:, :, None], idx_table, :].add(oe, mode="drop")[:, :t]

    if m.num_shared:
        s = params["shared"]
        hs = act(x @ s["w_gate"]["w"]) * (x @ s["w_up"]["w"])
        out = out + hs @ s["w_down"]["w"]

    # Switch-style load-balance loss (mean over groups).
    me = probs.mean(axis=(0, 1))                                        # [e]
    ce = jax.nn.one_hot(topi, e).sum(2).mean(axis=(0, 1))               # frac tokens
    aux = e * jnp.sum(me * ce)
    metrics = {"moe_aux": aux, "moe_dropped": dropped.astype(jnp.float32)}
    return out.astype(x.dtype), metrics
