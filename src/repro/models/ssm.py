"""Mamba2 (state-space duality / SSD) blocks in JAX.

Implements the chunked SSD algorithm: intra-chunk quadratic attention-like
term + inter-chunk state recurrence (lax.scan over chunks), which is the
TPU-friendly form (MXU matmuls inside chunks, O(T/chunk) sequential steps).
Decode keeps an O(1)-per-token recurrent state [B, H, P, N] plus a d_conv
rolling conv buffer — this is what makes SSM archs eligible for long_500k.

Faithful simplifications (noted in DESIGN.md): ngroups=1, no sequence
parallelism inside the chunk scan, gated RMSNorm as in the reference impl.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rmsnorm


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state  # x, B, C go through the conv
    return d_inner, nheads, conv_dim


def init(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    d_in_all = 2 * d_inner + 2 * s.d_state + nheads  # z, x, B, C, dt
    k = jax.random.split(key, 4)
    dt = jnp.exp(
        jax.random.uniform(k[3], (nheads,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
    )
    return {
        "in_proj": {"w": dense_init(k[0], (cfg.d_model, d_in_all), cfg.jdtype)},
        "conv": {
            "w": dense_init(k[1], (s.d_conv, conv_dim), cfg.jdtype, scale=0.5),
            "b": jnp.zeros((conv_dim,), cfg.jdtype),
        },
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse softplus
        "norm": {"w": jnp.ones((d_inner,), cfg.jdtype)},
        "out_proj": {"w": dense_init(k[2], (d_inner, cfg.d_model), cfg.jdtype)},
    }


def _split(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nheads, _ = dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + s.d_state, 2 * d_inner + 2 * s.d_state],
        axis=-1,
    )
    return z, x, B, C, dt


def _segsum_exp(a):
    """a: [..., q, h] per-step log decay -> L [..., h, q, q] with
    L[i, j] = exp(sum_{j<k<=i} a_k) for i >= j else 0."""
    q = a.shape[-2]
    cs = jnp.cumsum(a, axis=-2)                                   # [..., q, h]
    diff = cs[..., :, None, :] - cs[..., None, :, :]              # [..., i, j, h]
    iota = jnp.arange(q)
    mask = iota[:, None] >= iota[None, :]
    L = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    return jnp.moveaxis(L, -1, -3)                                # [..., h, i, j]


def ssd_scan(x, dt, A, B, C, chunk: int, compute_dtype=jnp.float32):
    """Chunked SSD. x: [b,t,h,p], dt: [b,t,h] (>=0), A: [h] (<0),
    B, C: [b,t,n] (ngroups=1). Returns (y [b,t,h,p], final_state [b,h,p,n]).

    ``compute_dtype``: dtype of the big intra-chunk einsum operands (L,
    decay-weighted x, B/C). bf16 halves the dominant HBM traffic (§Perf);
    accumulation and the inter-chunk recurrence stay f32.
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    pad = -t % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nc = tp // chunk
    cd = jnp.dtype(compute_dtype)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(cd)
    Cc = C.reshape(b, nc, chunk, n).astype(cd)
    a = dtc * A                                                   # [b,c,q,h]

    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(cd)    # [b,c,q,h,p]

    # --- intra-chunk (quadratic within chunk) ---
    L = _segsum_exp(a).astype(cd)                                 # [b,c,h,q,q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=jnp.float32).astype(cd)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, xdt,
                        preferred_element_type=jnp.float32)

    # --- chunk summary states ---
    a_cs = jnp.cumsum(a, axis=2)                                  # [b,c,q,h]
    a_tail = a_cs[:, :, -1:, :] - a_cs                            # decay to chunk end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc,
                        jnp.exp(a_tail).astype(cd), xdt,
                        preferred_element_type=jnp.float32)

    # --- inter-chunk recurrence ---
    a_sum = a_cs[:, :, -1, :]                                     # [b,c,h]

    def step(hprev, inp):
        st, asum = inp                                            # [b,h,p,n], [b,h]
        hnew = hprev * jnp.exp(asum)[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4), a_sum.transpose(1, 0, 2))
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                      # [b,c,h,p,n]

    # --- inter-chunk contribution ---
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc,
                       jnp.exp(a_cs).astype(cd), hprevs.astype(cd),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, tp, h, p)[:, :t]
    return y, hlast


def _conv1d(u, w, b, init_state=None):
    """Causal depthwise conv. u: [b, t, c], w: [k, c] -> [b, t, c]."""
    k = w.shape[0]
    if init_state is None:
        upad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        upad = jnp.concatenate([init_state.astype(u.dtype), u], axis=1)
    out = sum(
        upad[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def apply_seq(params, cfg: ModelConfig, h_in):
    """Full-sequence Mamba2 block. h_in: [b, t, d_model] -> same shape."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    zxbcdt = h_in @ params["in_proj"]["w"]
    z, xraw, Braw, Craw, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xraw, Braw, Craw], axis=-1)
    conv_out = jax.nn.silu(_conv1d(conv_in, params["conv"]["w"], params["conv"]["b"]))
    x, B, C = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    bsz, t, _ = h_in.shape
    xh = x.reshape(bsz, t, nheads, s.head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, _ = ssd_scan(xh, dtp, A, B, C, s.chunk,
                    compute_dtype=jnp.dtype(s.compute_dtype))
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, t, d_inner).astype(h_in.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm"]["w"], cfg.norm_eps)
    return y @ params["out_proj"]["w"]


def init_cache(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    return {
        "state": jnp.zeros((batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.float32),
    }


def apply_decode(params, cfg: ModelConfig, h_in, cache):
    """Single-token step. h_in: [b, 1, d_model] -> ([b, 1, d_model], cache)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    zxbcdt = h_in @ params["in_proj"]["w"]
    z, xraw, Braw, Craw, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xraw, Braw, Craw], axis=-1)      # [b, 1, c]
    conv_out = jax.nn.silu(
        _conv1d(conv_in, params["conv"]["w"], params["conv"]["b"],
                init_state=cache["conv"])
    )
    new_conv = jnp.concatenate([cache["conv"], conv_in.astype(jnp.float32)], axis=1)[:, 1:]
    x, B, C = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    bsz = h_in.shape[0]
    xh = x.reshape(bsz, nheads, s.head_dim).astype(jnp.float32)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [b,h]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dtp * A)                                        # [b,h]
    Bf = B[:, 0].astype(jnp.float32)                             # [b,n]
    Cf = C[:, 0].astype(jnp.float32)
    dBx = jnp.einsum("bn,bh,bhp->bhpn", Bf, dtp, xh)
    state = cache["state"] * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cf, state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(h_in.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm"]["w"], cfg.norm_eps)
    return y @ params["out_proj"]["w"], {"state": state, "conv": new_conv}
