"""Hierarchical leading-one detector as a Pallas TPU kernel (paper §II-B).

The FPGA circuit is an OuterLOD over a 128b summary vector followed by an
InnerLOD over the selected 32b word. On TPU the natural form is a fused
two-level reduction that the VPU executes on (8, 128)-tiled uint32 lanes:

  InnerLOD:  per word, clz via SWAR bit-smear + popcount (pure shifts/adds —
             no clz instruction needed on the VPU);
  OuterLOD:  per row, min-reduce of ``word_idx * 32 + clz`` keyed so the
             first nonzero word wins (empty words get a +inf key).

Block shape: rows of PEs are tiled by ``block_rows`` (sublane multiple of 8);
the word axis is padded to a 128-lane multiple by the wrapper so one block is
a whole number of VMEM tiles. The scheduler variant additionally clears the
selected bit in the same pass (one VMEM round-trip per scheduling decision).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32
_BIG = 0x7FFFFFFF  # empty-word key (python int to avoid captured tracers)


def _smear(w):
    w = w | (w >> 1)
    w = w | (w >> 2)
    w = w | (w >> 4)
    w = w | (w >> 8)
    return w | (w >> 16)


def _popcount(w):
    w = w - ((w >> 1) & _U32(0x55555555))
    w = (w & _U32(0x33333333)) + ((w >> 2) & _U32(0x33333333))
    w = (w + (w >> 4)) & _U32(0x0F0F0F0F)
    return ((w * _U32(0x01010101)) >> 24).astype(jnp.int32)


def _row_keys(bits):
    """[BP, W] uint32 -> [BP, W] int32 priority keys (lower = more critical)."""
    clz = 32 - _popcount(_smear(bits))
    w_idx = jax.lax.broadcasted_iota(jnp.int32, bits.shape, dimension=1)
    return jnp.where(bits != 0, w_idx * 32 + clz, _BIG)


def _lod_kernel(bits_ref, out_ref):
    keys = _row_keys(bits_ref[...])
    best = jnp.min(keys, axis=1)
    out_ref[...] = jnp.where(best == _BIG, jnp.int32(-1), best)


def _clear_bit(bits, s, do):
    """Clear bit for slot ``s`` [BP] in rows where ``do`` [BP]."""
    word = (s // 32)[:, None]
    w_idx = jax.lax.broadcasted_iota(jnp.int32, bits.shape, dimension=1)
    mask = (_U32(1) << (31 - (s % 32)).astype(_U32))[:, None]
    clear = (w_idx == word) & do[:, None]
    return jnp.where(clear, bits & ~mask, bits)


def _schedule_kernel(bits_ref, gate_ref, slot_ref, newbits_ref):
    bits = bits_ref[...]
    keys = _row_keys(bits)
    best = jnp.min(keys, axis=1)                      # [BP]
    have = best != _BIG
    slot_ref[...] = jnp.where(have, best, jnp.int32(-1))
    # Clear the selected bit only on gated rows (the simulator withholds the
    # commit while the exposed select latency is still draining).
    s = jnp.where(have, best, 0)
    newbits_ref[...] = _clear_bit(bits, s, have & (gate_ref[...] != 0))


def _rotating_schedule_kernel(bits_ref, ptr_ref, gate_ref, slot_ref, newbits_ref):
    """Rotating-pointer (least-recently-granted) pick for ``scan``/``lru_flat``:
    first ready slot at/after ``ptr`` (word-masked LOD), wrapping around to a
    plain LOD when the upper window is empty, fused with the gated clear."""
    bits = bits_ref[...]
    ptr = ptr_ref[...]                                # [BP] int32
    w_idx = jax.lax.broadcasted_iota(jnp.int32, bits.shape, dimension=1)
    pw = (ptr // 32)[:, None]
    pb = (ptr % 32).astype(_U32)[:, None]
    full = _U32(0xFFFFFFFF)
    ge_mask = jnp.where(w_idx > pw, full,
                        jnp.where(w_idx < pw, _U32(0), full >> pb))
    best_hi = jnp.min(_row_keys(bits & ge_mask), axis=1)
    best_all = jnp.min(_row_keys(bits), axis=1)
    best = jnp.where(best_hi != _BIG, best_hi, best_all)
    have = best_all != _BIG
    slot_ref[...] = jnp.where(have, best, jnp.int32(-1))
    s = jnp.where(have, best, 0)
    newbits_ref[...] = _clear_bit(bits, s, have & (gate_ref[...] != 0))


def _pad(bits, block_rows):
    p, w = bits.shape
    pp = -p % block_rows
    wp = -w % 128
    if pp or wp:
        bits = jnp.pad(bits, ((0, pp), (0, wp)))
    return bits, p, w


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def lod(bits: jax.Array, *, block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """[P, W] uint32 -> [P] int32 leading ready slot (or -1)."""
    padded, p, w = _pad(bits.astype(_U32), block_rows)
    pp, wp = padded.shape
    out = pl.pallas_call(
        _lod_kernel,
        grid=(pp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, wp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.int32),
        interpret=interpret,
    )(padded)
    return out[:p]


def _pad_rows(a, pp):
    p = a.shape[0]
    return jnp.pad(a, ((0, pp - p),)) if pp != p else a


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def schedule_step(
    bits: jax.Array, gate: jax.Array | None = None, *,
    block_rows: int = 256, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Fused pick + clear: [P, W] -> (slot [P] int32, new bits [P, W]).

    ``gate`` ([P] bool/int, default all-on) restricts the clear to gated
    rows; ungated rows still report their pick but keep the bit set (the
    simulator's exposed-select-latency stall).
    """
    padded, p, w = _pad(bits.astype(_U32), block_rows)
    pp, wp = padded.shape
    if gate is None:
        gate_i = jnp.ones((pp,), jnp.int32)
    else:
        gate_i = _pad_rows(gate.astype(jnp.int32), pp)
    slot, newbits = pl.pallas_call(
        _schedule_kernel,
        grid=(pp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, wp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, wp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp,), jnp.int32),
            jax.ShapeDtypeStruct((pp, wp), _U32),
        ],
        interpret=interpret,
    )(padded, gate_i)
    return slot[:p], newbits[:p, :w]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rotating_schedule_step(
    bits: jax.Array, ptr: jax.Array, gate: jax.Array | None = None, *,
    block_rows: int = 256, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Rotating-pointer pick + gated clear for the ``scan``/``lru_flat``
    policies: first ready slot at/after ``ptr`` (wrapping), cleared where
    ``gate``. [P, W] bits, [P] ptr -> (slot [P] int32, new bits [P, W]).
    Pointer advancement is cheap jnp on [P] and stays in the caller."""
    padded, p, w = _pad(bits.astype(_U32), block_rows)
    pp, wp = padded.shape
    ptr_i = _pad_rows(ptr.astype(jnp.int32), pp)
    if gate is None:
        gate_i = jnp.ones((pp,), jnp.int32)
    else:
        gate_i = _pad_rows(gate.astype(jnp.int32), pp)
    slot, newbits = pl.pallas_call(
        _rotating_schedule_kernel,
        grid=(pp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, wp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, wp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pp,), jnp.int32),
            jax.ShapeDtypeStruct((pp, wp), _U32),
        ],
        interpret=interpret,
    )(padded, ptr_i, gate_i)
    return slot[:p], newbits[:p, :w]
