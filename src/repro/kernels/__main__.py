"""Interpret-mode megakernel smoke: ``python -m repro.kernels --smoke``.

Fast end-to-end gate on the fused chunk engine (CI tier-1): for every
registered scheduler policy the megakernel run must be bit-identical to the
pure-jnp reference (stats AND node values), the fused chunk must lower to
exactly one ``pallas_call`` dispatch region, and one fig1-family graph
(served from the on-disk graph cache CI pre-warms — see
``workloads.warm_cache``) must reproduce its tracked cycle counts under
``engine="megakernel"``. Exits non-zero on any mismatch.

``--fig1`` alone skips the tiny-graph matrix and runs only the cached
fig1-family check (useful for cache debugging).
"""
from __future__ import annotations

import sys
import time


def _stats(r):
    return (r.done, r.cycles, r.deflections, r.busy_cycles, r.delivered)


def smoke(fig1_only: bool = False) -> None:
    import numpy as np

    from repro.core import schedulers
    from repro.core import workloads as wl
    from repro.api import run
    from repro.core.overlay import (OverlayConfig, device_graph, init_state,
                                    make_engine_chunk_fn)
    from repro.core.partition import build_graph_memory

    if not fig1_only:
        g = wl.layered_dag(4, 6, seed=3)
        for sched in sorted(schedulers.REGISTRY):
            gm = build_graph_memory(
                g, 2, 2,
                criticality_order=schedulers.get(sched).wants_criticality_order)
            ref = run(gm, OverlayConfig(scheduler=sched, check_every=1))
            r = run(gm, OverlayConfig(scheduler=sched, check_every=8,
                                           engine="megakernel"))
            assert _stats(r) == _stats(ref), (sched, _stats(r), _stats(ref))
            np.testing.assert_array_equal(r.values, ref.values)

            import jax

            cfg = OverlayConfig(scheduler=sched, engine="megakernel")
            dg = device_graph(gm)
            chunk = make_engine_chunk_fn(dg, cfg, 8)
            prims = [eqn.primitive.name
                     for eqn in jax.make_jaxpr(chunk)(init_state(dg, cfg)).jaxpr.eqns]
            assert prims.count("pallas_call") == 1, (sched, prims)
            assert "scan" not in prims, (sched, prims)
            print(f"megakernel_smoke_{sched},0.0,{r.cycles}")

    # One fig1-family row from the graph cache: the same graph the BENCH
    # megakernel section hot-times, here only checked for cycle equality.
    name = wl.MEGAKERNEL_BENCH_GRAPHS[0]
    g = wl.cached_graph(name, lambda: wl.arrow_lu_graph(4, 10, 8, seed=3))
    for sched in ("ooo", "inorder"):
        gm = build_graph_memory(
            g, 16, 16,
            criticality_order=schedulers.get(sched).wants_criticality_order)
        t0 = time.time()
        ref = run(gm, OverlayConfig(scheduler=sched, max_cycles=8_000_000))
        r = run(gm, OverlayConfig(scheduler=sched, max_cycles=8_000_000,
                                       engine="megakernel"))
        assert r.done and _stats(r) == _stats(ref), (sched, _stats(r),
                                                     _stats(ref))
        np.testing.assert_array_equal(r.values, ref.values)
        print(f"megakernel_smoke_fig1_{sched},"
              f"{round(1e6 * (time.time() - t0), 1)},{r.cycles}")
    print("MEGAKERNEL_SMOKE_OK")


def main(argv: list[str]) -> int:
    if "--smoke" in argv or "--fig1" in argv:
        smoke(fig1_only="--smoke" not in argv)
        return 0
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
