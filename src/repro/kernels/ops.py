"""Public jit'd wrappers for the Pallas kernels.

On the TPU target the kernels compile natively; on this CPU container they
run in ``interpret=True`` mode (the kernel body executed by the Pallas
interpreter), which is how tests validate them against :mod:`.ref`.
"""
from __future__ import annotations

import jax

from . import flash_attention as _fa
from . import lod as _lod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lod(bits, *, block_rows: int = 256):
    """Hierarchical leading-one detect: [P, W] uint32 -> [P] int32 (-1 empty)."""
    return _lod.lod(bits, block_rows=block_rows, interpret=_interpret())


def schedule_step(bits, gate=None, *, block_rows: int = 256):
    """Fused OoO scheduler step: pick leading ready slot and clear its flag
    (only on rows where ``gate``, all rows when None)."""
    return _lod.schedule_step(bits, gate, block_rows=block_rows,
                              interpret=_interpret())


def rotating_schedule_step(bits, ptr, gate=None, *, block_rows: int = 256):
    """Fused rotating-pointer scheduler step (``scan``/``lru_flat``): pick the
    first ready slot at/after ``ptr`` (wrapping) and clear it where ``gate``."""
    return _lod.rotating_schedule_step(bits, ptr, gate, block_rows=block_rows,
                                       interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 256, block_k: int = 256):
    """Blockwise attention: q [B,Hq,Tq,D], k/v [B,Hkv,Tkv,D] -> [B,Hq,Tq,D]."""
    return _fa.flash_attention(
        q, k, v, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )
