"""Blockwise (flash) attention Pallas TPU kernel — the LM stack's hot spot.

Online-softmax attention tiled for VMEM: the grid iterates KV blocks in the
last (sequential on TPU) axis, carrying running max / normalizer / output
accumulator in VMEM scratch, so the [Tq, Tkv] score matrix never exists in
HBM. Supports GQA (q-head -> kv-head mapped in the BlockSpec index_map) and
causal masking with ends-aligned q/kv (decode convention).

Block shapes: (block_q x head_dim) q tiles and (block_k x head_dim) kv tiles;
head_dim is padded to a 128-lane multiple by the wrapper, block_q/block_k are
sublane multiples. f32 accumulation regardless of input dtype.

The pure-jnp oracle is :func:`repro.kernels.ref.flash_attention_ref`; the
portable (non-Pallas) blockwise implementation used by the model stack on
any backend is :mod:`repro.models.attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, tq, tkv, nk):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # [BQ, BK]
    # Mask = kv-padding (kpos >= real tkv) plus causal (ends-aligned).
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < tkv
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (tkv - tq)
        valid = valid & (qpos >= kpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                   # [BQ, 128] (col 0 used)
    m_cur = jnp.max(s, axis=1, keepdims=True)             # [BQ, 1]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])         # [BQ, 1]
    p = jnp.exp(s - m_new[:, :1])                         # [BQ, BK]
    l_new = l_scr[...][:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...][:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, scale: float | None = None,
    block_q: int = 256, block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """q: [B, Hq, Tq, D], k/v: [B, Hkv, Tkv, D] -> [B, Hq, Tq, D]."""
    b, hq, tq, d = q.shape
    _, hkv, tkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    s = scale if scale is not None else 1.0 / (d ** 0.5)

    bq = min(block_q, tq)
    bk = min(block_k, tkv)
    dpad = -d % 128
    qpad, kpad = -tq % bq, -tkv % bk
    if dpad or qpad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, dpad)))
    if dpad or kpad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, dpad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, dpad)))
    tq_p, tkv_p, d_p = tq + qpad, tkv + kpad, d + dpad
    nq, nk = tq_p // bq, tkv_p // bk

    # ``tq``/``tkv`` passed to the kernel are the REAL lengths: kv padding is
    # rejected by the kpos bound, q padding is sliced off after the call.
    kernel = functools.partial(_kernel, scale=s, causal=causal, tq=tq, tkv=tkv, nk=nk)

    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d_p), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d_p), lambda bb, h, i, j, rep=rep: (bb, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d_p), lambda bb, h, i, j, rep=rep: (bb, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d_p), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_p, d_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d_p), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :tq, :d]
