"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; tests sweep shapes/dtypes and
``assert_allclose`` the kernel (run with ``interpret=True`` on CPU) against
these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
EMPTY = jnp.int32(-1)


def lod_ref(bits: jax.Array) -> jax.Array:
    """Hierarchical leading-one detect (paper §II-B), reference.

    bits: [..., W] uint32, slot s lives at word s//32, bit (31 - s%32).
    Returns [...] int32: index of the first set flag in (word, MSB-first)
    order — with criticality-ordered memory this is the most critical ready
    node — or -1 if empty.
    """
    nonzero = bits != 0
    word_idx = jnp.argmax(nonzero, axis=-1).astype(jnp.int32)
    sel = jnp.take_along_axis(bits, word_idx[..., None], axis=-1)[..., 0]
    clz = jax.lax.clz(sel.astype(jnp.uint32)).astype(jnp.int32)
    slot = word_idx * 32 + clz
    return jnp.where(nonzero.any(axis=-1), slot, EMPTY)


def popcount_ref(w: jax.Array) -> jax.Array:
    return jax.lax.population_count(w.astype(_U32)).astype(jnp.int32)


def _clear_slot_ref(bits, slot, do):
    s = jnp.clip(slot, 0, bits.shape[-1] * 32 - 1)
    word = s // 32
    mask = (_U32(1) << (31 - (s % 32)).astype(_U32))
    row = jnp.take_along_axis(bits, word[..., None], axis=-1)[..., 0]
    cleared = jnp.where(do, row & ~mask, row)
    return jnp.put_along_axis(bits, word[..., None], cleared[..., None],
                              axis=-1, inplace=False)


def schedule_step_ref(bits: jax.Array,
                      gate: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused scheduler step: pick the leading ready slot per row AND clear its
    flag (on rows where ``gate``; every row when None).
    bits: [P, W] uint32 -> (slot [P] int32, new_bits [P, W])."""
    slot = lod_ref(bits)
    have = slot >= 0
    do = have if gate is None else have & (gate != 0)
    return slot, _clear_slot_ref(bits, slot, do)


def rotating_schedule_step_ref(
    bits: jax.Array, ptr: jax.Array, gate: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Rotating-pointer scheduler step oracle: first ready slot at/after
    ``ptr`` per row, wrapping to a plain LOD when the upper window is empty;
    the pick's flag is cleared on rows where ``gate``."""
    W = bits.shape[-1]
    word_ids = jnp.arange(W, dtype=jnp.int32)
    pw = (ptr // 32)[..., None]
    pb = (ptr % 32).astype(_U32)[..., None]
    full = _U32(0xFFFFFFFF)
    ge_mask = jnp.where(word_ids > pw, full,
                        jnp.where(word_ids < pw, _U32(0), full >> pb))
    hi = lod_ref(bits & ge_mask)
    slot = jnp.where(hi >= 0, hi, lod_ref(bits))
    have = slot >= 0
    do = have if gate is None else have & (gate != 0)
    return slot, _clear_slot_ref(bits, slot, do)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    scale: float | None = None, kv_seg: jax.Array | None = None,
) -> jax.Array:
    """Exact attention oracle. q: [B, Hq, Tq, D], k/v: [B, Hkv, Tkv, D].

    GQA: Hq must be a multiple of Hkv; kv heads are repeated. ``kv_seg``
    optionally masks padded kv positions ([B, Tkv] bool, True == attend).
    Causal masking aligns the *ends* of q and kv (decode convention).
    """
    b, hq, tq, d = q.shape
    _, hkv, tkv, _ = k.shape
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * s
    if causal:
        qpos = jnp.arange(tq) + (tkv - tq)
        mask = qpos[:, None] >= jnp.arange(tkv)[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    if kv_seg is not None:
        logits = jnp.where(kv_seg[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
