"""Fused K-cycle overlay chunk as ONE Pallas kernel (the "megakernel").

The paper's overlay sustains 300 soft processors at 250MHz because scheduler
select (tag match + leading-one detect), Hoplite routing, and eject all
resolve inside a single hardware cycle. The software analogue in
:mod:`repro.core.overlay` pays ~5 separate jnp dispatch regions per simulated
cycle glued by ``lax.scan`` — every region re-materializes the full state
from HBM. This module fuses the *entire chunk* instead: one
``pl.pallas_call`` whose kernel body runs ``check_every`` back-to-back
cycles — scheduler select for every registered policy (via the
``Scheduler.step`` protocol, same code path the PR-2 ``schedule_step`` /
``rotating_schedule_step`` kernels generalized), the unidirectional-Hoplite
torus route, the fused per-port eject scatters, and the remaining-nodes
termination counter — with operand/dependency state carried across cycles in
kernel refs (VMEM on TPU) rather than round-tripped per dispatch.

State layout in refs
--------------------
The simulation state pytree (see ``overlay.init_state``) and the
``DeviceGraph`` dict are flattened to leaf arrays in canonical pytree order;
each leaf becomes one kernel ref (graph leaves are read-only inputs, state
leaves are inputs with matching outputs). Rank-0 leaves (``cycle``,
``remaining``, ``done``, the stat counters) ride as shape-``(1,)`` refs and
are reshaped back inside the kernel. The kernel loads every leaf once,
iterates the cycle body ``K`` times in a ``fori_loop`` with the whole state
as the carry, records the per-cycle ``done`` flag into a ``[K]`` (or
``[K, B]`` batched) trace ref, and stores the final state once.

K-cycle carry + exactness
-------------------------
The in-kernel cycle body IS ``overlay.make_cycle_fn`` — the same pure-jnp
transition the reference engine scans, traced into the kernel instead of
into an XLA while-loop body. That makes bit-exactness an identity, not a
re-derivation: the chunk repair (completion-cycle recovery from the done
trace, once-per-chunk stat reduction) is the same arithmetic as
``overlay.make_chunk_fn``, applied to the kernel's outputs. The pure-jnp
chunked path stays the reference oracle; ``tests/test_megakernel.py`` pins
every policy x chunk depth x engine combination bit-for-bit, and the BENCH
``megakernel`` section gates the fig1-family cycle counts.

Fallback semantics
------------------
The kernel cannot contain cross-shard collectives, so the sharded engines
(:mod:`repro.core.distributed`) route ``engine="megakernel"`` through the
fused chunk only when both mesh axes are size 1 (torus shifts are then pure
local rolls); real multi-shard meshes silently fall back to the jnp chunk,
whose per-chunk psum/pmin already amortizes the collectives. On non-TPU
backends the kernel executes in Pallas interpret mode (the validated CI
configuration); bool-dtype refs and the dynamic per-PE gathers inside the
cycle body are interpret/TPU-Mosaic-maturity territory, which is why the
jnp path remains the default engine.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import overlay


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def make_mega_chunk_fn(
    g: dict,
    cfg: "overlay.OverlayConfig",
    check_every: int,
    *,
    scheduler=None,
    batched: bool = False,
    all_reduce: Callable[[Any], Any] = lambda x: x,
    interpret: bool | None = None,
):
    """Build ``chunk(state) -> state`` running ``check_every`` cycles in one
    ``pallas_call`` — the ``engine="megakernel"`` counterpart of
    ``overlay.make_chunk_fn(cycle_fn, check_every)``.

    ``batched=True`` builds the vmapped-cycle variant for the batched sweep
    engine (state leaves carry a leading config axis; the done trace becomes
    ``[K, B]`` and the chunk repair runs per element). ``all_reduce`` is the
    once-per-chunk cross-shard reduction (identity on a single device) and
    stays *outside* the kernel, exactly like the jnp chunk.
    """
    if interpret is None:
        interpret = _interpret()
    sched = overlay._resolve(cfg, scheduler)
    K = int(check_every)
    g_leaves, g_tree = jax.tree_util.tree_flatten(dict(g))

    def chunk(s):
        s_leaves, s_tree = jax.tree_util.tree_flatten(s)
        n_g, n_s = len(g_leaves), len(s_leaves)
        # Rank-0 leaves ride as (1,) refs; remember the true shapes.
        s_shapes = [l.shape for l in s_leaves]
        trace_shape = (K,) + tuple(s["done"].shape)

        def kernel(*refs):
            g_vals = [refs[i][...] for i in range(n_g)]
            s_vals = [refs[n_g + i][...].reshape(s_shapes[i])
                      for i in range(n_s)]
            out_refs = refs[n_g + n_s:]
            gv = jax.tree_util.tree_unflatten(g_tree, g_vals)
            sv = jax.tree_util.tree_unflatten(s_tree, s_vals)
            # The reference cycle body, traced INTO the kernel: select +
            # route + eject + termination stay fused across all K cycles.
            cycle = overlay.make_cycle_fn(gv, cfg, scheduler=sched)
            if batched:
                cycle = jax.vmap(cycle)

            def body(k, carry):
                st, trace = carry
                st = cycle(st)
                trace = jax.lax.dynamic_update_index_in_dim(
                    trace, st["done"], k, 0)
                return st, trace

            trace0 = jnp.zeros(trace_shape, jnp.bool_)
            st, trace = jax.lax.fori_loop(0, K, body, (sv, trace0))
            for r, leaf in zip(out_refs[:n_s], jax.tree_util.tree_leaves(st)):
                r[...] = leaf.reshape(r.shape)
            out_refs[n_s][...] = trace

        at_least_1d = lambda l: l.reshape((1,)) if l.ndim == 0 else l
        out_shape = [jax.ShapeDtypeStruct(at_least_1d(l).shape, l.dtype)
                     for l in s_leaves]
        out_shape.append(jax.ShapeDtypeStruct(trace_shape, jnp.bool_))
        res = pl.pallas_call(kernel, out_shape=out_shape,
                             interpret=interpret)(
            *g_leaves, *(at_least_1d(l) for l in s_leaves))
        s2 = jax.tree_util.tree_unflatten(
            s_tree, [r.reshape(shp) for r, shp in zip(res[:-1], s_shapes)])
        done_trace = res[-1]

        # Chunk repair — the same arithmetic as overlay.make_chunk_fn,
        # applied along the in-chunk axis 0 (elementwise over any batch
        # axis, so batched repair == vmap of the solo repair).
        keys = overlay.stat_keys(s)
        start_stats = jnp.stack([s[k] for k in keys])
        start_cycle = s["cycle"]
        start_done = s["done"]
        done_trace = all_reduce(done_trace)            # one collective
        any_done = done_trace.any(axis=0)
        first = jnp.argmax(done_trace, axis=0).astype(jnp.int32)
        cycle_ct = jnp.where(
            start_done, start_cycle,
            jnp.where(any_done, start_cycle + first + 1, s2["cycle"]))
        end_stats = jnp.stack([s2[k] for k in keys])
        stats = start_stats + all_reduce(end_stats - start_stats)

        out = dict(s2, done=any_done, cycle=cycle_ct)
        for i, k in enumerate(keys):
            out[k] = stats[i]
        if "telem" in out:
            # Telemetry leaves ride the state pytree into kernel refs like
            # any other leaf, so the fused engine gets full traces for free;
            # only the fixed-point overshoot repair happens out here.
            out["telem"] = overlay.repair_telemetry(
                out["telem"], s2["cycle"] - cycle_ct)
        return out

    return chunk
