"""Fault-tolerant checkpointing: atomic manifest-based save/restore.

Layout:  <dir>/step_<N>/manifest.json + leaf_<i>.npy (one file per pytree
leaf), written to a tmp dir then atomically renamed, so a crash mid-save
never corrupts the latest checkpoint. ``LATEST`` is a one-line pointer file
updated after the rename. Restore reads the manifest, so the checkpoint is
self-describing (no template needed, though one can be supplied to validate
structure). An async mode hands the save to a writer thread (the train loop
continues; ``wait()`` joins before exit or the next async save).

Multi-host notes (documented for the 1000-node deployment): each process
saves only addressable shards under <dir>/step_N/proc_<k>/ with the same
manifest scheme; restore re-shards via jax.device_put with the target
sharding. On this single-process container the proc dimension is 1.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree) -> str:
    """Atomic synchronous save. Returns the final step directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "num_leaves": len(leaves),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(path, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(path, "LATEST.tmp"), os.path.join(path, "LATEST"))
    return final


def latest_step(path: str) -> int | None:
    p = os.path.join(path, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(path: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (validates leaf count/shapes)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten_with_paths(like)
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, template has {len(leaves)}")
    out = []
    for i, tmpl in enumerate(leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if hasattr(tmpl, "shape") and tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != template {tmpl.shape}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, path: str, keep_n: int = 3, async_save: bool = True):
        self.path = path
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        # Pull to host before handing to the writer thread.
        host_tree = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree)

    def _save_and_gc(self, step, tree):
        save(self.path, step, tree)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, like):
        return restore(self.path, like)

    def latest_step(self):
        return latest_step(self.path)
