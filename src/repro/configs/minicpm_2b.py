"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, trained with WSD.

40L, d_model 2304, 36 heads (GQA kv=36 == MHA), d_ff 5760, vocab 122753.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, act="silu", pos="rope",
    tie_embeddings=True,  # MiniCPM ties embeddings
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=256, act="silu", pos="rope",
    tie_embeddings=True, dtype="float32", attn_chunk=32, loss_chunk=32,
)
