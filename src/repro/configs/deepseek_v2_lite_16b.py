"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE.

27L, d_model 2048, 16 heads, vocab 102400. MoE: 64 routed experts top-6 +
2 shared, expert d_ff 1408; layer 0 is a dense FFN (d_ff 10944).
NOTE: the assignment sheet says "2 shared+160 routed top-6" next to "MoE 64e
top-6"; 64 routed matches both the "64e" field and the HF release, so we use
64 routed (+2 shared) and record the discrepancy here.
"""
from repro.models.common import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    fsdp=True,
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, act="silu", pos="rope",
    mla=MLACfg(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
               first_dense_layers=1),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=256, act="silu", pos="rope",
    mla=MLACfg(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
    moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=64, num_shared=1,
               first_dense_layers=1),
    dtype="float32", attn_chunk=32, loss_chunk=32,
)
