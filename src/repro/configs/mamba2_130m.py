"""Mamba2-130M [arXiv:2405.21060; unverified] — SSD, attention-free.

24L, d_model 768, ssm_state 128, vocab 50280. head_dim 64, expand 2.
"""
from repro.models.common import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, pos="none",
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256, pos="none",
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    tie_embeddings=True, dtype="float32", attn_chunk=32, loss_chunk=32,
)
