from .registry import ARCHS, SHAPES, get_config, input_specs, shape_applicable  # noqa: F401
