"""Architecture registry + assigned input shapes.

ARCHS maps arch id -> (full ModelConfig, reduced smoke ModelConfig).
SHAPES are the assignment's four (seq_len, global_batch, kind) cells.
``shape_applicable`` implements the assignment's skip rules:
  * ``long_500k`` only for sub-quadratic archs (SSM state or MLA latent
    cache); pure full-attention archs skip it (recorded in DESIGN.md).
  * all archs here are decoder-bearing, so decode shapes always apply.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "gemma-2b": "gemma_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-130m": "mamba2_130m",
    "zamba2-7b": "zamba2_7b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(applicable, reason-if-not)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 512K dense KV cache is the quadratic regime (DESIGN.md)"
    return True, ""


def input_specs(arch: str, shape: str):
    """Raw (seq_len, batch, kind) plus per-arch semantics adjustments.

    Whisper: seq_len == encoder frames; decoder length = seq_len // dec_ratio
    (train/prefill) and decode steps use a seq_len//dec_ratio-deep self cache.
    VLM: train/prefill inputs are stub patch embeddings [b, t, d_model].
    """
    cfg = get_config(arch)
    s = SHAPES[shape]
    spec = {"arch": arch, "shape": shape, "kind": s.kind,
            "seq_len": s.seq_len, "global_batch": s.global_batch}
    if cfg.encdec is not None:
        spec["enc_len"] = s.seq_len
        spec["dec_len"] = max(64, s.seq_len // cfg.encdec.dec_ratio)
    if cfg.family == "vlm":
        spec["embeds"] = True
    return spec
