"""Qwen2-0.5B [arXiv:2407.10671; hf] — GQA (kv=2), QKV bias, tied embeddings.

24L, d_model 896, 14 heads (kv=2), d_ff 4864, vocab 151936.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, act="silu", pos="rope", qkv_bias=True,
    tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256, act="silu", pos="rope", qkv_bias=True,
    tie_embeddings=True, dtype="float32", attn_chunk=32, loss_chunk=32,
)
