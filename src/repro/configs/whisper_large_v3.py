"""Whisper-large-v3 [arXiv:2212.04356; unverified] — encoder-decoder.

32 decoder + 32 encoder layers, d_model 1280, 20 heads (kv=20), d_ff 5120,
vocab 51866. Conv frontend STUBBED per assignment: input_specs() provides
precomputed frame embeddings [b, t_enc, d_model]. LayerNorm, plain GELU MLP
with biases, sinusoidal positions, tied decoder embeddings.
"""
from repro.models.common import EncDecCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, act="gelu", pos="sinusoid",
    norm="layernorm", mlp_glu=False, qkv_bias=True, proj_bias=True,
    tie_embeddings=True,
    encdec=EncDecCfg(enc_layers=32, dec_ratio=8),
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, act="gelu", pos="sinusoid",
    norm="layernorm", mlp_glu=False, qkv_bias=True, proj_bias=True,
    tie_embeddings=True,
    encdec=EncDecCfg(enc_layers=2, dec_ratio=8),
    dtype="float32", attn_chunk=32, loss_chunk=32,
)
