"""Qwen2-VL-72B [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution.

Backbone only (assignment): 80L, d_model 8192, 64 heads (kv=8), d_ff 29568,
vocab 152064. The vision frontend is a STUB: input_specs() provides
precomputed patch embeddings [b, t, d_model]; M-RoPE runs with text ids
(t==h==w), the real code path with degenerate positions.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    fsdp=True,
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, act="silu", pos="mrope", qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=256, act="silu", pos="mrope", qkv_bias=True,
    dtype="float32", attn_chunk=32, loss_chunk=32,
)
