"""DBRX-132B [hf:databricks/dbrx-base; unverified] — fine-grained MoE,
16 experts top-4. 40L, d_model 6144, 48 heads (kv=8), expert d_ff 10752,
vocab 100352. Total ~132B params, ~36B active.
"""
from repro.models.common import ModelConfig, MoECfg

CONFIG = ModelConfig(
    fsdp=True,
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, act="silu", pos="rope",
    rope_theta=500_000.0,
    moe=MoECfg(num_experts=16, top_k=4, d_ff_expert=10752),
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, act="silu", pos="rope",
    moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=128),
    dtype="float32", attn_chunk=32, loss_chunk=32,
)
