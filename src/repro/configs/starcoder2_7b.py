"""StarCoder2-7B [arXiv:2402.19173; hf] — GQA (kv=4), RoPE, plain GELU MLP
with biases. 32L, d_model 4608, 36 heads, d_ff 18432, vocab 49152.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    fsdp=True,
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152, act="gelu", pos="rope",
    mlp_glu=False, qkv_bias=True, proj_bias=True, norm="layernorm",
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, act="gelu", pos="rope",
    mlp_glu=False, qkv_bias=True, proj_bias=True, norm="layernorm",
    dtype="float32", attn_chunk=32, loss_chunk=32,
)
