"""Gemma-2B [arXiv:2403.08295; hf] — GeGLU, head_dim=256, MQA (kv=1).

18L, d_model 2048, 8 heads (kv=1), d_ff 16384, vocab 256000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, act="gelu", pos="rope",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=256, act="gelu", pos="rope",
    tie_embeddings=True, dtype="float32", attn_chunk=32, loss_chunk=32,
)
