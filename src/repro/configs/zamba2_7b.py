"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 + shared attention.

81L, d_model 3584, 32 heads (kv=32, head_dim 112), d_ff 14336, ssm_state 64,
vocab 32000. Interpretation (recorded per DESIGN.md): every 7th layer is an
application of ONE shared attention+FFN block (11 applications, distinct KV
caches); the remaining 70 layers are Mamba2 (expand 2, head_dim 64). The
real model's per-application LoRA deltas and embedding-concat input are
omitted (noted in DESIGN.md §Arch-applicability).
"""
from repro.models.common import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    fsdp=True,
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, act="silu", pos="rope",
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=7,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, act="silu", pos="rope",
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    attn_every=3, dtype="float32", attn_chunk=32, loss_chunk=32,
)
