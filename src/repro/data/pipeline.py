"""Deterministic, host-sharded data pipeline.

Sources:
  * SyntheticCopyTask — sequences whose second half repeats the first
    (learnable by attention, SSM and hybrid models alike); used by the
    loss-decrease tests and the e2e training example.
  * SyntheticZipfLM — zipf-distributed token soup (throughput benchmarking).
  * MemmapCorpus — np.memmap token file for real corpora.

Every batch is a function of (seed, step, host), so restarts resume the
stream exactly (checkpoint stores the step) and each host reads only its
shard of the global batch — no coordination needed at 1000-node scale.
A small prefetch thread hides host-side generation latency.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticCopyTask:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0):
        assert batch % num_hosts == 0
        self.vocab, self.seq, self.seed = vocab, seq, seed
        self.local_batch = batch // num_hosts
        self.host_id = host_id

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        half = (self.seq + 1) // 2
        prefix = rng.integers(2, self.vocab, (self.local_batch, half), dtype=np.int32)
        full = np.concatenate([prefix, prefix], axis=1)[:, : self.seq + 1]
        full[:, half] = 1  # SEP
        tokens, labels = full[:, :-1], full[:, 1:]
        mask = np.zeros_like(labels, dtype=np.float32)
        mask[:, half:] = 1.0  # only the copied half is scored
        return {"tokens": tokens, "labels": labels.astype(np.int32), "mask": mask}


class SyntheticZipfLM:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 num_hosts: int = 1, host_id: int = 0, alpha: float = 1.2):
        assert batch % num_hosts == 0
        self.vocab, self.seq, self.seed, self.alpha = vocab, seq, seed, alpha
        self.local_batch = batch // num_hosts
        self.host_id = host_id

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        z = rng.zipf(self.alpha, (self.local_batch, self.seq + 1))
        toks = (np.minimum(z, self.vocab - 1)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapCorpus:
    """Flat token file (uint16/uint32). Sampling is deterministic in step."""

    def __init__(self, path: str, dtype, vocab: int, batch: int, seq: int,
                 seed: int = 0, num_hosts: int = 1, host_id: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab, self.seq, self.seed = vocab, seq, seed
        self.local_batch = batch // num_hosts
        self.host_id = host_id

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        hi = len(self.data) - self.seq - 1
        starts = rng.integers(0, hi, self.local_batch)
        rows = np.stack([self.data[s : s + self.seq + 1] for s in starts]).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class Prefetcher:
    """Background-thread prefetch over ``dataset.batch_at(step)``."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.dataset.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
