"""End-to-end driver: train a small LM for a few hundred steps with WSD
AdamW, deterministic data, checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_lm.py            # ~200 steps
    PYTHONPATH=src python examples/train_lm.py --resume   # continues

This is the same production driver the cluster would run
(repro.launch.train); kill it mid-run and rerun to see restart recovery.
"""
import sys

from repro.launch.train import main

args = [
    "--arch", "qwen2-0.5b", "--smoke",
    "--steps", "200", "--batch", "16", "--seq", "32",
    "--lr", "1e-2", "--ckpt", "/tmp/repro_train_lm", "--ckpt-every", "50",
]
main(args + sys.argv[1:])
