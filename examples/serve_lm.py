"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-130m]
"""
import sys

from repro.launch.serve import main

argv = sys.argv[1:]
if not any(a.startswith("--arch") for a in argv):
    argv = ["--arch", "qwen2-0.5b"] + argv
main(argv + ["--smoke", "--batch", "8", "--prompt-len", "32", "--gen", "24"])
