"""Quickstart: run a sparse-matrix-factorization dataflow graph on the
out-of-order token-dataflow overlay and compare against in-order FCFS.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import workloads as wl
from repro.core.graph import reference_evaluate
from repro import run
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory

# 1. A dataflow graph: LU factorization of a bordered block-diagonal matrix
#    (the structure of circuit/power-grid matrices).
graph = wl.arrow_lu_graph(blocks=8, block_size=10, border=8, seed=0)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges")

# 2. Reference answer (topological evaluation).
ref = reference_evaluate(graph)

# 3. Place it on a 16x16 overlay, local memories in decreasing criticality
#    order (the paper's static labeling), and simulate cycle-accurately.
for sched in ("ooo", "inorder"):
    gm = build_graph_memory(graph, 16, 16, criticality_order=(sched == "ooo"))
    res = run(gm, OverlayConfig(scheduler=sched))
    ok = np.allclose(res.values, ref, rtol=1e-5, atol=1e-5)
    print(f"{sched:8s}: {res.cycles:6d} cycles | values match reference: {ok} "
          f"| NoC deflections: {res.deflections}")
