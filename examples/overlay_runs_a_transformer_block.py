"""The overlay executes ANY dataflow DAG — including a transformer block.

Builds the op-level dataflow graph of a (tiny) attention + FFN block with
GraphBuilder, labels it by criticality, and executes it on the 8x8 overlay
under both schedulers, validating against numpy. This is the integration
demo for DESIGN.md §4: the paper's engine as a general scheduling substrate.

    PYTHONPATH=src python examples/overlay_runs_a_transformer_block.py
"""
import numpy as np

from repro.core.graph import OP_ADD, OP_MUL, GraphBuilder, reference_evaluate
from repro import run
from repro.core.overlay import OverlayConfig
from repro.core.partition import build_graph_memory

rng = np.random.default_rng(0)
D, T = 8, 6  # tiny: d_model 8, 6 tokens

b = GraphBuilder()
X = [[b.input(rng.uniform(0.5, 1.5)) for _ in range(D)] for _ in range(T)]
Wq = [[b.input(rng.uniform(-0.3, 0.3)) for _ in range(D)] for _ in range(D)]
Wv = [[b.input(rng.uniform(-0.3, 0.3)) for _ in range(D)] for _ in range(D)]


def matvec(W, x):
    out = []
    for row in W:
        acc = b.op(OP_MUL, row[0], x[0])
        for wi, xi in zip(row[1:], x[1:]):
            acc = b.op(OP_ADD, acc, b.op(OP_MUL, wi, xi))
        out.append(acc)
    return out


Q = [matvec(Wq, x) for x in X]
V = [matvec(Wv, x) for x in X]
# linear attention surrogate: y_t = sum_{s<=t} (q_t . q_s) * v_s  (keeps the
# DAG realistic: dot products + weighted accumulation, causal structure)
Y = []
for t in range(T):
    acc = None
    for s in range(t + 1):
        dot = b.op(OP_MUL, Q[t][0], Q[s][0])
        for i in range(1, D):
            dot = b.op(OP_ADD, dot, b.op(OP_MUL, Q[t][i], Q[s][i]))
        contrib = [b.op(OP_MUL, dot, V[s][i]) for i in range(D)]
        acc = contrib if acc is None else [b.op(OP_ADD, a, c) for a, c in zip(acc, contrib)]
    Y.append(acc)

g = b.build()
ref = reference_evaluate(g)
print(f"transformer-block DAG: {g.num_nodes} nodes, {g.num_edges} edges")
for sched in ("ooo", "inorder"):
    gm = build_graph_memory(g, 8, 8, criticality_order=(sched == "ooo"))
    r = run(gm, OverlayConfig(scheduler=sched))
    ok = np.allclose(r.values, ref, rtol=1e-4, atol=1e-4)
    print(f"{sched:8s}: {r.cycles:5d} cycles | matches numpy: {ok}")
